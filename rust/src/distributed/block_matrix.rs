//! `BlockMatrix` (paper §2.3): sub-blocks in an RDD keyed by block
//! coordinates. Supports `add`, `multiply` (the paper's "large linear
//! model parallelism" [4, 9] builds on it), `transpose`, and the paper's
//! `validate` helper.
//!
//! Each block is a [`Block`]: dense, or CSR when `from_coordinate` finds
//! it at or below [`SPARSE_BLOCK_MAX_DENSITY`] fill — sparse inputs stay
//! sparse through block ops instead of densifying at conversion.
//!
//! `multiply` is Spark's **simulate multiply**: both operands'
//! block-key sets are collected (metadata only), the destination
//! partitions of every block under the result's [`Partitioner::grid`]
//! are computed on the driver, and each block is shipped — `Arc`-shared,
//! never deep-cloned — *only* to the reduce partitions it actually
//! contracts with, in ONE shuffle. Each reduce partition accumulates its
//! partial products in place, dispatching the `C += A·B` kernel by the
//! operand pair's formats ([`gemm_acc`] for dense×dense, the
//! `linalg::sparse` `spmm_acc` family otherwise; per-format counts land
//! in `Metrics::spmm_*`). An operand already partitioned so that all its
//! blocks sit at their destination is read in place — zero shuffle for
//! that side (`Metrics::shuffles_skipped`). The legacy join-based
//! two-shuffle path survives as [`BlockMatrix::multiply_join`] for
//! benchmarks.

use std::collections::{BTreeSet, HashMap};
use std::sync::atomic::Ordering;
use std::sync::{Arc, OnceLock};

use crate::coordinator::context::Context;
use crate::distributed::coordinate_matrix::{CoordinateMatrix, MatrixEntry};
use crate::error::{Error, Result};
use crate::linalg::blas::level3::gemm_acc;
use crate::linalg::matrix::DenseMatrix;
use crate::linalg::sparse::{spmm_acc_ds, spmm_acc_ss, CsrMatrix};
use crate::rdd::core::Prep;
use crate::rdd::pair::Partitioner;
use crate::rdd::shuffle::ShuffleDep;
use crate::rdd::{Metrics, Rdd, ShuffleRerun};

/// `from_coordinate` keeps a block sparse when its fill fraction
/// (entries / rows·cols) is at or below this threshold; denser blocks
/// materialize dense. 1-in-4 fill is roughly where the CSR row walk
/// stops beating the dense row walk for the block sizes in play.
pub const SPARSE_BLOCK_MAX_DENSITY: f64 = 0.25;

/// One stored sub-block of a [`BlockMatrix`]: dense, or row-compressed
/// for blocks that arrive sparse from coordinate data.
#[derive(Debug, Clone)]
pub enum Block {
    /// Dense storage.
    Dense(DenseMatrix),
    /// CSR storage (block-local indices).
    Sparse(CsrMatrix),
}

impl Block {
    /// Row count.
    pub fn rows(&self) -> usize {
        match self {
            Block::Dense(m) => m.rows,
            Block::Sparse(s) => s.rows,
        }
    }

    /// Column count.
    pub fn cols(&self) -> usize {
        match self {
            Block::Dense(m) => m.cols,
            Block::Sparse(s) => s.cols,
        }
    }

    /// True for CSR storage.
    pub fn is_sparse(&self) -> bool {
        matches!(self, Block::Sparse(_))
    }

    /// Nonzero count (explicit stored zeros excluded, matching the other
    /// formats' accounting).
    pub fn nnz(&self) -> usize {
        match self {
            Block::Dense(m) => m.data.iter().filter(|&&x| x != 0.0).count(),
            Block::Sparse(s) => s.values.iter().filter(|&&x| x != 0.0).count(),
        }
    }

    /// Sum of squared stored values.
    pub fn frob_sq(&self) -> f64 {
        match self {
            Block::Dense(m) => {
                let f = m.frob_norm();
                f * f
            }
            Block::Sparse(s) => s.frob_sq(),
        }
    }

    /// Densify (clones for dense blocks).
    pub fn to_dense(&self) -> DenseMatrix {
        match self {
            Block::Dense(m) => m.clone(),
            Block::Sparse(s) => s.to_dense(),
        }
    }

    /// Transpose, preserving storage format.
    pub fn transpose(&self) -> Block {
        match self {
            Block::Dense(m) => Block::Dense(m.transpose()),
            Block::Sparse(s) => Block::Sparse(s.transpose()),
        }
    }

    /// Scale every value, preserving storage format.
    pub fn scale(&self, alpha: f64) -> Block {
        match self {
            Block::Dense(m) => Block::Dense(m.scale(alpha)),
            Block::Sparse(s) => Block::Sparse(s.scale(alpha)),
        }
    }

    /// `self += other` in place. Dense absorbs sparse by scatter;
    /// sparse += sparse merges and stays sparse; sparse += dense
    /// densifies (the sum is as dense as the dense operand).
    pub fn add_assign(&mut self, other: &Block) -> Result<()> {
        if (self.rows(), self.cols()) != (other.rows(), other.cols()) {
            return Err(Error::dim(format!(
                "block add: {}x{} vs {}x{}",
                self.rows(),
                self.cols(),
                other.rows(),
                other.cols()
            )));
        }
        if let Block::Sparse(a) = &*self {
            let merged = match other {
                Block::Sparse(b) => {
                    let mut entries: Vec<(usize, usize, f64)> = a.iter_entries().collect();
                    entries.extend(b.iter_entries());
                    Block::Sparse(CsrMatrix::from_coo(a.rows, a.cols, entries)?)
                }
                Block::Dense(b) => {
                    let mut d = a.to_dense();
                    d.add_assign(b)?;
                    Block::Dense(d)
                }
            };
            *self = merged;
            return Ok(());
        }
        let Block::Dense(a) = self else { unreachable!("sparse handled above") };
        match other {
            Block::Dense(b) => a.add_assign(b),
            Block::Sparse(b) => {
                for (i, j, v) in b.iter_entries() {
                    let cur = a.get(i, j);
                    a.set(i, j, cur + v);
                }
                Ok(())
            }
        }
    }

    /// `self + other`, allocating (the legacy `multiply_join` combiner).
    pub fn add(&self, other: &Block) -> Result<Block> {
        let mut out = self.clone();
        out.add_assign(other)?;
        Ok(out)
    }

    /// `c += a·b`, dispatching the kernel by the operand pair's storage
    /// formats and counting the dispatch in `metrics` — the contraction
    /// inside simulate-multiply. The accumulator is always dense:
    /// products of sparse blocks fill in fast, so Gustavson with a dense
    /// accumulator is the right sparse×sparse shape here.
    pub fn spmm_acc(a: &Block, b: &Block, c: &mut DenseMatrix, metrics: &Metrics) {
        match (a, b) {
            (Block::Dense(am), Block::Dense(bm)) => {
                metrics.spmm_dense_dense.fetch_add(1, Ordering::Relaxed);
                gemm_acc(am, bm, c);
            }
            (Block::Sparse(am), Block::Dense(bm)) => {
                metrics.spmm_sparse_dense.fetch_add(1, Ordering::Relaxed);
                am.spmm_acc(bm, c);
            }
            (Block::Dense(am), Block::Sparse(bm)) => {
                metrics.spmm_dense_sparse.fetch_add(1, Ordering::Relaxed);
                spmm_acc_ds(am, bm, c);
            }
            (Block::Sparse(am), Block::Sparse(bm)) => {
                metrics.spmm_sparse_sparse.fetch_add(1, Ordering::Relaxed);
                spmm_acc_ss(am, bm, c);
            }
        }
    }

    /// `self·other` as a fresh dense matrix (stripe Gram, legacy join
    /// multiply — paths without a shared accumulator or dispatch
    /// counters).
    pub fn matmul(&self, other: &Block) -> Result<DenseMatrix> {
        crate::ensure_dims!(self.cols(), other.rows(), "block matmul inner dims");
        let mut c = DenseMatrix::zeros(self.rows(), other.cols());
        match (self, other) {
            (Block::Dense(am), Block::Dense(bm)) => gemm_acc(am, bm, &mut c),
            (Block::Sparse(am), Block::Dense(bm)) => am.spmm_acc(bm, &mut c),
            (Block::Dense(am), Block::Sparse(bm)) => spmm_acc_ds(am, bm, &mut c),
            (Block::Sparse(am), Block::Sparse(bm)) => spmm_acc_ss(am, bm, &mut c),
        }
        Ok(c)
    }
}

/// Block-partitioned distributed matrix.
#[derive(Clone)]
pub struct BlockMatrix {
    /// ((block_row, block_col), block) records.
    pub blocks: Rdd<((usize, usize), Block)>,
    /// Rows per (full) block.
    pub rows_per_block: usize,
    /// Cols per (full) block.
    pub cols_per_block: usize,
    /// Total rows.
    pub num_rows: usize,
    /// Total cols.
    pub num_cols: usize,
    ctx: Context,
}

impl BlockMatrix {
    /// Wrap a blocks RDD (callers promise block sizes; `validate()` checks).
    pub fn new(
        ctx: &Context,
        blocks: Rdd<((usize, usize), Block)>,
        rows_per_block: usize,
        cols_per_block: usize,
        num_rows: usize,
        num_cols: usize,
    ) -> BlockMatrix {
        BlockMatrix { blocks, rows_per_block, cols_per_block, num_rows, num_cols, ctx: ctx.clone() }
    }

    /// Split a local matrix into blocks.
    pub fn from_local(
        ctx: &Context,
        a: &DenseMatrix,
        rows_per_block: usize,
        cols_per_block: usize,
        num_partitions: usize,
    ) -> BlockMatrix {
        let mut blocks = vec![];
        for bi in 0..a.rows.div_ceil(rows_per_block) {
            for bj in 0..a.cols.div_ceil(cols_per_block) {
                let r0 = bi * rows_per_block;
                let c0 = bj * cols_per_block;
                let nr = rows_per_block.min(a.rows - r0);
                let nc = cols_per_block.min(a.cols - c0);
                blocks.push(((bi, bj), Block::Dense(a.block(r0, c0, nr, nc))));
            }
        }
        BlockMatrix::new(
            ctx,
            ctx.parallelize(blocks, num_partitions),
            rows_per_block,
            cols_per_block,
            a.rows,
            a.cols,
        )
    }

    /// From coordinate entries (one shuffle; the paper's
    /// `CoordinateMatrix.toBlockMatrix`). Output blocks are
    /// grid-partitioned, so downstream block ops see a known
    /// [`Partitioner`] and can skip compatible shuffles.
    ///
    /// Blocks whose fill fraction is at or below
    /// [`SPARSE_BLOCK_MAX_DENSITY`] are stored CSR instead of dense, so
    /// sparse inputs keep their memory/flops advantage through block
    /// ops. The decision uses the raw (pre-dedup) entry count — an
    /// upper bound on distinct nonzeros, so it never misclassifies a
    /// sparse block as dense.
    pub fn from_coordinate(
        cm: &CoordinateMatrix,
        rows_per_block: usize,
        cols_per_block: usize,
        num_partitions: usize,
    ) -> Result<BlockMatrix> {
        let (nr, nc) = (cm.num_rows as usize, cm.num_cols as usize);
        let rpb = rows_per_block;
        let cpb = cols_per_block;
        let part =
            Partitioner::grid(nr.div_ceil(rpb), nc.div_ceil(cpb), num_partitions.max(1));
        let keyed = cm
            .entries
            .map(move |e| (((e.i as usize / rpb), (e.j as usize / cpb)), *e));
        let grouped = keyed.combine_by_key_with(
            part.clone(),
            |e| vec![e],
            |acc: &mut Vec<MatrixEntry>, e| acc.push(e),
            |acc: &mut Vec<MatrixEntry>, mut other| acc.append(&mut other),
        );
        let blocks = grouped
            .map(move |((bi, bj), entries)| {
                let (bi, bj) = (*bi, *bj);
                let block_rows = rpb.min(nr - bi * rpb);
                let block_cols = cpb.min(nc - bj * cpb);
                let area = block_rows * block_cols;
                let blk = if entries.len() as f64 <= SPARSE_BLOCK_MAX_DENSITY * area as f64 {
                    let coo: Vec<(usize, usize, f64)> = entries
                        .iter()
                        .map(|e| (e.i as usize - bi * rpb, e.j as usize - bj * cpb, e.value))
                        .collect();
                    Block::Sparse(
                        CsrMatrix::from_coo(block_rows, block_cols, coo)
                            .expect("block-local indices are in range by construction"),
                    )
                } else {
                    let mut m = DenseMatrix::zeros(block_rows, block_cols);
                    for e in entries {
                        let li = e.i as usize - bi * rpb;
                        let lj = e.j as usize - bj * cpb;
                        let cur = m.get(li, lj);
                        m.set(li, lj, cur + e.value);
                    }
                    Block::Dense(m)
                };
                ((bi, bj), blk)
            })
            // keys are untouched by the block build, so the grid
            // placement survives the map
            .with_partitioner(part);
        Ok(BlockMatrix::new(cm.context(), blocks, rpb, cpb, nr, nc))
    }

    /// Owning context.
    pub fn context(&self) -> &Context {
        &self.ctx
    }

    /// Cache the backing blocks.
    pub fn cache(&self) -> BlockMatrix {
        BlockMatrix {
            blocks: self.blocks.clone().cache(),
            rows_per_block: self.rows_per_block,
            cols_per_block: self.cols_per_block,
            num_rows: self.num_rows,
            num_cols: self.num_cols,
            ctx: self.ctx.clone(),
        }
    }

    /// Nonzeros stored inside blocks (explicit zeros excluded, matching
    /// the other formats' accounting).
    pub fn nnz(&self) -> Result<usize> {
        self.blocks.aggregate(0usize, |a, (_k, m)| a + m.nnz(), |a, b| a + b)
    }

    /// Explode blocks into coordinate entries (no shuffle — entries stay
    /// in their block's partition; the reverse of `from_coordinate`).
    pub fn to_coordinate_matrix(&self) -> CoordinateMatrix {
        let (rpb, cpb) = (self.rows_per_block, self.cols_per_block);
        let entries = self.blocks.flat_map(move |((bi, bj), blk)| {
            let (r0, c0) = (*bi * rpb, *bj * cpb);
            let mut out = vec![];
            match blk {
                Block::Dense(m) => {
                    for i in 0..m.rows {
                        for j in 0..m.cols {
                            let v = m.get(i, j);
                            if v != 0.0 {
                                out.push(MatrixEntry {
                                    i: (r0 + i) as u64,
                                    j: (c0 + j) as u64,
                                    value: v,
                                });
                            }
                        }
                    }
                }
                Block::Sparse(s) => {
                    for (i, j, v) in s.iter_entries() {
                        if v != 0.0 {
                            out.push(MatrixEntry {
                                i: (r0 + i) as u64,
                                j: (c0 + j) as u64,
                                value: v,
                            });
                        }
                    }
                }
            }
            out
        });
        CoordinateMatrix::new(&self.ctx, entries, self.num_rows as u64, self.num_cols as u64)
    }

    /// Regroup into sparse indexed rows (one shuffle, via coordinates).
    pub fn to_indexed_row_matrix(
        &self,
        num_partitions: usize,
    ) -> Result<crate::distributed::indexed_row_matrix::IndexedRowMatrix> {
        self.to_coordinate_matrix().to_indexed_row_matrix(num_partitions)
    }

    /// Regroup into rows, dropping indices (one shuffle).
    pub fn to_row_matrix(
        &self,
        num_partitions: usize,
    ) -> Result<crate::distributed::row_matrix::RowMatrix> {
        Ok(self.to_indexed_row_matrix(num_partitions)?.to_row_matrix())
    }

    /// Block-grid dimensions.
    pub fn grid(&self) -> (usize, usize) {
        (
            self.num_rows.div_ceil(self.rows_per_block),
            self.num_cols.div_ceil(self.cols_per_block),
        )
    }

    /// The paper's `validate()`: checks block indices are in range, block
    /// shapes match their grid slot, and no duplicate indices exist.
    pub fn validate(&self) -> Result<()> {
        let (gr, gc) = self.grid();
        let (rpb, cpb) = (self.rows_per_block, self.cols_per_block);
        let (nr, nc) = (self.num_rows, self.num_cols);
        let issues = self.blocks.map(move |((bi, bj), m)| {
            let (bi, bj) = (*bi, *bj);
            let mut problems: Vec<String> = vec![];
            if bi >= gr || bj >= gc {
                problems.push(format!("block ({bi},{bj}) outside {gr}x{gc} grid"));
            } else {
                let want_r = rpb.min(nr - bi * rpb);
                let want_c = cpb.min(nc - bj * cpb);
                if (m.rows(), m.cols()) != (want_r, want_c) {
                    problems.push(format!(
                        "block ({bi},{bj}) is {}x{}, expected {want_r}x{want_c}",
                        m.rows(),
                        m.cols()
                    ));
                }
            }
            ((bi, bj), problems)
        });
        let collected = issues.collect()?;
        let mut seen = std::collections::HashSet::new();
        for ((bi, bj), problems) in collected {
            if let Some(p) = problems.first() {
                return Err(Error::Validation(p.clone()));
            }
            if !seen.insert((bi, bj)) {
                return Err(Error::Validation(format!("duplicate block index ({bi},{bj})")));
            }
        }
        Ok(())
    }

    /// Element-wise add. Identically-partitioned operands (e.g. two
    /// products over the same grid) add with a partition-local zip —
    /// zero shuffle; otherwise one grid-partitioned merge shuffle whose
    /// combiner folds blocks in place ([`Block::add_assign`]; sparse
    /// pairs stay sparse, mixed pairs densify).
    pub fn add(&self, other: &BlockMatrix) -> Result<BlockMatrix> {
        if (self.num_rows, self.num_cols) != (other.num_rows, other.num_cols)
            || (self.rows_per_block, self.cols_per_block)
                != (other.rows_per_block, other.cols_per_block)
        {
            return Err(Error::dim(format!(
                "BlockMatrix add: {}x{} ({}x{} blocks) vs {}x{} ({}x{} blocks)",
                self.num_rows,
                self.num_cols,
                self.rows_per_block,
                self.cols_per_block,
                other.num_rows,
                other.num_cols,
                other.rows_per_block,
                other.cols_per_block
            )));
        }
        if let (Some(p1), Some(p2)) = (self.blocks.partitioner(), other.blocks.partitioner()) {
            if p1 == p2 && self.blocks.num_partitions() == other.blocks.num_partitions() {
                let shared = p1.clone();
                self.ctx
                    .cluster()
                    .metrics
                    .shuffles_skipped
                    .fetch_add(1, Ordering::Relaxed);
                let summed = self
                    .blocks
                    .zip_partitions(&other.blocks, |ls, rs| {
                        let mut acc: HashMap<(usize, usize), Block> =
                            ls.iter().map(|(k, m)| (*k, m.clone())).collect();
                        for (k, m) in rs {
                            match acc.get_mut(k) {
                                // lint:allow(SL006) shapes validated at construction
                                Some(a) => a.add_assign(m).expect("validated block shapes"),
                                None => {
                                    acc.insert(*k, m.clone());
                                }
                            }
                        }
                        acc.into_iter().collect()
                    })?
                    .with_partitioner(shared);
                return Ok(BlockMatrix::new(
                    &self.ctx,
                    summed,
                    self.rows_per_block,
                    self.cols_per_block,
                    self.num_rows,
                    self.num_cols,
                ));
            }
        }
        let parts = self.blocks.num_partitions().max(other.blocks.num_partitions());
        let (gr, gc) = self.grid();
        let part = Partitioner::grid(gr, gc, parts);
        let tagged = self
            .blocks
            .map(|(k, m)| (*k, m.clone()))
            .union(&other.blocks.map(|(k, m)| (*k, m.clone())));
        let summed = tagged.reduce_by_key_merge(part, |acc: &mut Block, m| {
            acc.add_assign(&m).expect("validated block shapes")
        });
        Ok(BlockMatrix::new(
            &self.ctx,
            summed,
            self.rows_per_block,
            self.cols_per_block,
            self.num_rows,
            self.num_cols,
        ))
    }

    /// Re-partition blocks spatially with a [`Partitioner::grid`] sized
    /// for roughly `suggested_partitions` tiles. A no-op (zero shuffle,
    /// counted in `Metrics::shuffles_skipped`) when the blocks already
    /// carry that exact partitioner.
    pub fn partition_by_grid(&self, suggested_partitions: usize) -> BlockMatrix {
        let (gr, gc) = self.grid();
        let part = Partitioner::grid(gr, gc, suggested_partitions.max(1));
        BlockMatrix::new(
            &self.ctx,
            self.blocks.partition_by_with(part),
            self.rows_per_block,
            self.cols_per_block,
            self.num_rows,
            self.num_cols,
        )
    }

    /// Distributed matrix multiply — Spark's **simulate multiply**:
    ///
    /// 1. at the first consuming action (the op itself is lazy, like
    ///    every other transformation), collect both operands' block keys
    ///    (metadata only) and compute, on the driver, the set of result
    ///    partitions each block contracts with under the result grid
    ///    partitioner;
    /// 2. ONE shuffle routes every block — `Arc`-shared, cloned only by
    ///    pointer — to exactly those destinations (a side whose blocks
    ///    already all sit at their destination is read in place, zero
    ///    shuffle, `Metrics::shuffles_skipped`);
    /// 3. each result partition runs the local block contraction,
    ///    accumulating partial products **in place** into a dense
    ///    accumulator via [`Block::spmm_acc`] — the kernel is picked per
    ///    block pair ([`gemm_acc`] only when both sides are dense), with
    ///    per-format dispatch counts in `Metrics::spmm_*` and no
    ///    per-partial allocations.
    ///
    /// The output is grid-partitioned, so follow-up block ops over the
    /// same grid skip their shuffles. Note the planning key-pass streams
    /// each *uncached* operand's lineage once before the routing pass
    /// reads it again — `cache()` operands that are expensive to
    /// recompute (exactly Spark's guidance for `BlockMatrix.multiply`).
    pub fn multiply(&self, other: &BlockMatrix) -> Result<BlockMatrix> {
        if self.num_cols != other.num_rows || self.cols_per_block != other.rows_per_block {
            return Err(Error::dim(format!(
                "BlockMatrix multiply: inner {} ({}per) vs {} ({}per)",
                self.num_cols, self.cols_per_block, other.num_rows, other.rows_per_block
            )));
        }
        let (gr_a, _) = self.grid();
        let (_, gc_b) = other.grid();
        let suggested = self.blocks.num_partitions().max(other.blocks.num_partitions());
        let part = Partitioner::grid(gr_a, gc_b, suggested);
        let num_out = part.num_partitions();
        let cluster = Arc::clone(self.ctx.cluster());
        let shuffle_id = cluster.new_id();

        // ---- lazy plan: simulate + route at the first action's prep.
        // The plan decides per side whether to read in place (already at
        // its destinations) or to ship under the ONE shared shuffle id.
        let plan: Arc<OnceLock<(MulSide, MulSide)>> = Arc::new(OnceLock::new());
        let a_blocks = self.blocks.clone();
        let b_blocks = other.blocks.clone();
        let part_plan = part.clone();
        let cluster_plan = Arc::clone(&cluster);
        let plan_w = Arc::clone(&plan);
        let dep = ShuffleDep::new(
            Arc::clone(&cluster),
            shuffle_id,
            Box::new(move || {
                // simulate: block keys only, destinations on the driver
                let a_keys: Vec<(usize, usize)> = a_blocks.map(|(k, _m)| *k).collect()?;
                let b_keys: Vec<(usize, usize)> = b_blocks.map(|(k, _m)| *k).collect()?;
                let mut a_is_by_k: HashMap<usize, Vec<usize>> = HashMap::new();
                for &(i, k) in &a_keys {
                    a_is_by_k.entry(k).or_default().push(i);
                }
                let mut b_js_by_k: HashMap<usize, Vec<usize>> = HashMap::new();
                for &(k, j) in &b_keys {
                    b_js_by_k.entry(k).or_default().push(j);
                }
                let mut a_dests: HashMap<(usize, usize), BTreeSet<usize>> = HashMap::new();
                for &(i, k) in &a_keys {
                    let dests = a_dests.entry((i, k)).or_default();
                    if let Some(js) = b_js_by_k.get(&k) {
                        for &j in js {
                            dests.insert(part_plan.partition_coords(i, j));
                        }
                    }
                }
                let mut b_dests: HashMap<(usize, usize), BTreeSet<usize>> = HashMap::new();
                for &(k, j) in &b_keys {
                    let dests = b_dests.entry((k, j)).or_default();
                    if let Some(is) = a_is_by_k.get(&k) {
                        for &i in is {
                            dests.insert(part_plan.partition_coords(i, j));
                        }
                    }
                }
                let (a_src, a_shuffled) =
                    route_mul_side(&a_blocks, &part_plan, &a_dests, shuffle_id, 0, &cluster_plan)?;
                let (b_src, b_shuffled) = route_mul_side(
                    &b_blocks,
                    &part_plan,
                    &b_dests,
                    shuffle_id,
                    a_blocks.num_partitions(),
                    &cluster_plan,
                )?;
                let _ = plan_w.set((a_src, b_src));
                Ok(a_shuffled || b_shuffled)
            }),
        );
        // co-located sides are read in place at reduce time, so both
        // operands' upstream stages must be prepared before our jobs
        let mut preps: Vec<Arc<Prep>> = self.blocks.child_preps();
        preps.extend(other.blocks.child_preps());
        preps.push(dep.as_prep());

        // ---- reduce: local contraction with in-place accumulation
        let (rpb, cpb_out) = (self.rows_per_block, other.cols_per_block);
        let (nr_out, nc_out) = (self.num_rows, other.num_cols);
        let part_c = part.clone();
        let cluster2 = Arc::clone(&cluster);
        let compute = Box::new(move |q: usize, exec: usize| {
            // `dep` must outlive this RDD so the buckets do too
            let _keep = &dep;
            let (a_src, b_src) = plan
                .get()
                .ok_or_else(|| Error::msg("BlockMatrix multiply plan not prepared"))?;
            let (a_buckets, a_local) = gather_mul_side(a_src, &cluster2, shuffle_id, q, exec)?;
            let (b_buckets, b_local) = gather_mul_side(b_src, &cluster2, shuffle_id, q, exec)?;
            let mut a_refs: Vec<(usize, usize, &Block)> = Vec::new();
            for bucket in &a_buckets {
                for ((i, k), m) in bucket.iter() {
                    a_refs.push((*i, *k, m.as_ref()));
                }
            }
            if let Some(data) = &a_local {
                for ((i, k), m) in data.iter() {
                    a_refs.push((*i, *k, m));
                }
            }
            let mut b_by_k: HashMap<usize, Vec<(usize, &Block)>> = HashMap::new();
            for bucket in &b_buckets {
                for ((k, j), m) in bucket.iter() {
                    b_by_k.entry(*k).or_default().push((*j, m.as_ref()));
                }
            }
            if let Some(data) = &b_local {
                for ((k, j), m) in data.iter() {
                    b_by_k.entry(*k).or_default().push((*j, m));
                }
            }
            let mut out: HashMap<(usize, usize), DenseMatrix> = HashMap::new();
            for &(i, k, am) in &a_refs {
                if let Some(bs) = b_by_k.get(&k) {
                    for &(j, bm) in bs {
                        // a block pair may co-reside here on behalf of a
                        // *different* output partition — contract only
                        // the products this partition owns
                        if part_c.partition_coords(i, j) != q {
                            continue;
                        }
                        let c = out.entry((i, j)).or_insert_with(|| {
                            DenseMatrix::zeros(
                                rpb.min(nr_out - i * rpb),
                                cpb_out.min(nc_out - j * cpb_out),
                            )
                        });
                        Block::spmm_acc(am, bm, c, &cluster2.metrics);
                    }
                }
            }
            Ok(out.into_iter().map(|(k, c)| (k, Block::Dense(c))).collect())
        });
        let result = Rdd::from_parts(
            Arc::clone(&cluster),
            format!("({}·{})", self.blocks.name(), other.blocks.name()),
            num_out,
            preps,
            compute,
        )
        .with_partitioner(part);
        Ok(BlockMatrix::new(
            &self.ctx,
            result,
            self.rows_per_block,
            other.cols_per_block,
            self.num_rows,
            other.num_cols,
        ))
    }

    /// The legacy two-shuffle multiply (join on the contraction index k,
    /// one fresh matrix per partial product, reduce by allocating add) —
    /// kept as the regression baseline `bench_shuffle` measures the
    /// simulate-multiply against.
    pub fn multiply_join(&self, other: &BlockMatrix) -> Result<BlockMatrix> {
        if self.num_cols != other.num_rows || self.cols_per_block != other.rows_per_block {
            return Err(Error::dim(format!(
                "BlockMatrix multiply: inner {} ({}per) vs {} ({}per)",
                self.num_cols, self.cols_per_block, other.num_rows, other.rows_per_block
            )));
        }
        let parts = self.blocks.num_partitions().max(other.blocks.num_partitions());
        let a_by_k = self.blocks.map(|((i, k), m)| (*k, (*i, m.clone())));
        let b_by_k = other.blocks.map(|((k, j), m)| (*k, (*j, m.clone())));
        let joined = a_by_k.join(&b_by_k, parts);
        let partials = joined.map(|(_k, ((i, a), (j, b)))| {
            ((*i, *j), Block::Dense(a.matmul(b).expect("inner block dims validated")))
        });
        let reduced = partials
            .reduce_by_key(parts, |x: &Block, y: &Block| x.add(y).expect("partial shapes agree"));
        Ok(BlockMatrix::new(
            &self.ctx,
            reduced,
            self.rows_per_block,
            other.cols_per_block,
            self.num_rows,
            other.num_cols,
        ))
    }

    /// Transpose (blocks transpose locally; indices swap).
    pub fn transpose(&self) -> BlockMatrix {
        let blocks = self.blocks.map(|((i, j), m)| ((*j, *i), m.transpose()));
        BlockMatrix::new(
            &self.ctx,
            blocks,
            self.cols_per_block,
            self.rows_per_block,
            self.num_cols,
            self.num_rows,
        )
    }

    /// Scale every block.
    pub fn scale(&self, alpha: f64) -> BlockMatrix {
        let blocks = self.blocks.map(move |(k, m)| (*k, m.scale(alpha)));
        BlockMatrix::new(
            &self.ctx,
            blocks,
            self.rows_per_block,
            self.cols_per_block,
            self.num_rows,
            self.num_cols,
        )
    }

    /// Collect to a local dense matrix (tests / small results).
    pub fn to_local(&self) -> Result<DenseMatrix> {
        let mut out = DenseMatrix::zeros(self.num_rows, self.num_cols);
        for ((bi, bj), blk) in self.blocks.collect()? {
            let r0 = bi * self.rows_per_block;
            let c0 = bj * self.cols_per_block;
            match blk {
                Block::Dense(m) => {
                    for i in 0..m.rows {
                        for j in 0..m.cols {
                            let cur = out.get(r0 + i, c0 + j);
                            out.set(r0 + i, c0 + j, cur + m.get(i, j));
                        }
                    }
                }
                Block::Sparse(s) => {
                    for (i, j, v) in s.iter_entries() {
                        let cur = out.get(r0 + i, c0 + j);
                        out.set(r0 + i, c0 + j, cur + v);
                    }
                }
            }
        }
        Ok(out)
    }

    /// Force every block dense (same geometry and partitioner) — the
    /// baseline `bench_sparse` compares the sparse-aware multiply
    /// against.
    pub fn densify(&self) -> BlockMatrix {
        let blocks = self.blocks.map(|(k, b)| (*k, Block::Dense(b.to_dense())));
        let blocks = match self.blocks.partitioner() {
            Some(p) => blocks.with_partitioner(p.clone()),
            None => blocks,
        };
        BlockMatrix::new(
            &self.ctx,
            blocks,
            self.rows_per_block,
            self.cols_per_block,
            self.num_rows,
            self.num_cols,
        )
    }

    /// Convert dense blocks at or below `max_density` fill to CSR
    /// (sparse blocks pass through; geometry and partitioner are
    /// preserved). The inverse pressure of [`BlockMatrix::densify`].
    pub fn sparsify(&self, max_density: f64) -> BlockMatrix {
        let blocks = self.blocks.map(move |(k, b)| {
            let blk = match b {
                Block::Dense(m)
                    if (m.data.iter().filter(|&&x| x != 0.0).count() as f64)
                        <= max_density * (m.rows * m.cols) as f64 =>
                {
                    Block::Sparse(CsrMatrix::from_dense(m))
                }
                other => other.clone(),
            };
            (*k, blk)
        });
        let blocks = match self.blocks.partitioner() {
            Some(p) => blocks.with_partitioner(p.clone()),
            None => blocks,
        };
        BlockMatrix::new(
            &self.ctx,
            blocks,
            self.rows_per_block,
            self.cols_per_block,
            self.num_rows,
            self.num_cols,
        )
    }
}

/// One operand of the simulate-multiply: read in place (already at its
/// destinations) or routed there under the multiply's single shuffle.
enum MulSide {
    Colocated(Rdd<((usize, usize), Block)>),
    /// Map partitions of this side live at `base..base + n_map` within
    /// the shared shuffle id's map-index space.
    Shuffled { base: usize, n_map: usize },
}

/// Route one operand toward the result partitions (called from the
/// multiply's `ShuffleDep` at the first consuming action): skip the
/// shuffle when every block already sits at its sole destination under
/// the operand's recorded partitioner, else run the routing map job now
/// — blocks consumed by value and shipped `Arc`-shared to exactly their
/// destination set. Returns the side plus whether it actually shuffled.
///
/// This intentionally parallels `rdd::pair`'s `SideSource` but is a
/// separate mechanism: it fans each record out to a *set* of
/// destinations, shares one payload `Arc` across them, and offsets its
/// map indices by `base` inside a shuffle id shared with the other
/// operand.
fn route_mul_side(
    blocks: &Rdd<((usize, usize), Block)>,
    part: &Partitioner,
    dests: &HashMap<(usize, usize), BTreeSet<usize>>,
    shuffle_id: usize,
    base: usize,
    cluster: &Arc<crate::rdd::Cluster>,
) -> Result<(MulSide, bool)> {
    let colocated = blocks.partitioner().is_some_and(|p| {
        p.num_partitions() == part.num_partitions()
            && blocks.num_partitions() == part.num_partitions()
            && dests
                .iter()
                .all(|(key, ds)| ds.iter().all(|&q| q == p.partition_coords(key.0, key.1)))
    });
    if colocated {
        cluster.metrics.shuffles_skipped.fetch_add(1, Ordering::Relaxed);
        return Ok((MulSide::Colocated(blocks.clone()), false));
    }
    blocks.prepare()?;
    let parent = blocks.clone();
    let cl = Arc::clone(cluster);
    let dests = Arc::new(dests.clone());
    let num_out = part.num_partitions();
    let n_map = blocks.num_partitions();
    // shared routing task: the full stage now, and exactly the lost map
    // partitions again if a reduce-side fetch misses (stage-level lineage)
    let route_task: Arc<dyn Fn(usize, usize) -> Result<()> + Send + Sync> =
        Arc::new(move |p, exec| {
            let mut buckets: Vec<Vec<((usize, usize), Arc<Block>)>> =
                (0..num_out).map(|_| Vec::new()).collect();
            for (key, m) in parent.compute_owned(p, exec)? {
                if let Some(ds) = dests.get(&key) {
                    if ds.is_empty() {
                        continue; // contracts with nothing: never shipped
                    }
                    // one shared payload, pointer-cloned per destination
                    let shared = Arc::new(m);
                    for &q in ds.iter() {
                        buckets[q].push((key, Arc::clone(&shared)));
                    }
                }
            }
            for (b, bucket) in buckets.into_iter().enumerate() {
                if !bucket.is_empty() {
                    cl.shuffle.put(shuffle_id, base + p, b, bucket);
                }
            }
            // register under the side's base offset, even for all-empty
            // maps: a reduce-side miss then means "lost", not "empty"
            cl.shuffle.register_map_output(shuffle_id, base + p, exec);
            Ok(())
        });
    cluster.run_job(n_map, Arc::clone(&route_task))?;
    let cl_rerun = Arc::clone(cluster);
    cluster.register_map_rerun(
        shuffle_id,
        ShuffleRerun {
            base,
            n_map,
            handler: Arc::new(move |lost| {
                let lost = lost.to_vec();
                let task = Arc::clone(&route_task);
                cl_rerun.run_job(lost.len(), Arc::new(move |i, exec| task(lost[i], exec)))?;
                Ok(())
            }),
        },
    );
    Ok((MulSide::Shuffled { base, n_map }, true))
}

type MulBuckets = Vec<Arc<Vec<((usize, usize), Arc<Block>)>>>;
type MulLocal = Option<Arc<Vec<((usize, usize), Block)>>>;

/// Fetch one side's blocks for result partition `q` — shuffle buckets
/// for a routed side, the in-place partition for a co-located one. Both
/// come back as keep-alive containers the contraction borrows from, so
/// no block is ever deep-copied on the read side.
fn gather_mul_side(
    side: &MulSide,
    cluster: &Arc<crate::rdd::Cluster>,
    shuffle_id: usize,
    q: usize,
    exec: usize,
) -> Result<(MulBuckets, MulLocal)> {
    match side {
        MulSide::Colocated(rdd) => Ok((Vec::new(), Some(rdd.materialize(q, exec)?))),
        MulSide::Shuffled { base, n_map } => {
            let mut buckets = Vec::new();
            for m in 0..*n_map {
                // loss-detecting read: a missing map output raises
                // FetchFailed and the scheduler re-routes that partition
                if let Some(b) = cluster
                    .shuffle
                    .fetch::<((usize, usize), Arc<Block>)>(shuffle_id, base + m, q)?
                {
                    buckets.push(b);
                }
            }
            Ok((buckets, None))
        }
    }
}

impl crate::rdd::memory::SizeOf for Block {
    fn heap_bytes(&self) -> usize {
        use crate::rdd::memory::SizeOf;
        match self {
            Block::Dense(m) => m.heap_bytes(),
            Block::Sparse(s) => s.heap_bytes(),
        }
    }
}

impl crate::rdd::memory::Spill for Block {
    fn encode(&self, out: &mut Vec<u8>) {
        use crate::rdd::memory::Spill;
        match self {
            Block::Dense(m) => {
                out.push(0);
                m.encode(out);
            }
            Block::Sparse(s) => {
                out.push(1);
                s.encode(out);
            }
        }
    }

    fn decode(src: &mut &[u8]) -> crate::error::Result<Self> {
        use crate::rdd::memory::Spill;
        match u8::decode(src)? {
            0 => DenseMatrix::decode(src).map(Block::Dense),
            1 => CsrMatrix::decode(src).map(Block::Sparse),
            _ => Err(Error::msg("spill decode: invalid Block tag")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::SplitMix64;

    fn ctx() -> Context {
        Context::local("block_test", 2)
    }

    #[test]
    fn from_local_roundtrip_property() {
        check("blockmatrix to_local == original", 8, |g| {
            let c = ctx();
            let r = 1 + g.int(0, 20);
            let cc = 1 + g.int(0, 20);
            let a = DenseMatrix::randn(r, cc, g.rng());
            let rpb = 1 + g.int(0, 6);
            let cpb = 1 + g.int(0, 6);
            let bm = BlockMatrix::from_local(&c, &a, rpb, cpb, 3);
            bm.validate().unwrap();
            assert!(bm.to_local().unwrap().max_abs_diff(&a) < 1e-12);
        });
    }

    #[test]
    fn add_matches_local_property() {
        check("block add == local add", 6, |g| {
            let c = ctx();
            let r = 1 + g.int(0, 15);
            let cc = 1 + g.int(0, 15);
            let a = DenseMatrix::randn(r, cc, g.rng());
            let b = DenseMatrix::randn(r, cc, g.rng());
            let rpb = 1 + g.int(0, 4);
            let cpb = 1 + g.int(0, 4);
            let ba = BlockMatrix::from_local(&c, &a, rpb, cpb, 2);
            let bb = BlockMatrix::from_local(&c, &b, rpb, cpb, 3);
            let sum = ba.add(&bb).unwrap().to_local().unwrap();
            assert!(sum.max_abs_diff(&a.add(&b).unwrap()) < 1e-12);
        });
    }

    #[test]
    fn multiply_matches_local_property() {
        check("block multiply == local matmul", 6, |g| {
            let c = ctx();
            let m = 1 + g.int(0, 12);
            let k = 1 + g.int(0, 12);
            let n = 1 + g.int(0, 12);
            let a = DenseMatrix::randn(m, k, g.rng());
            let b = DenseMatrix::randn(k, n, g.rng());
            let rpb = 1 + g.int(0, 4);
            let inner = 1 + g.int(0, 4);
            let cpb = 1 + g.int(0, 4);
            let ba = BlockMatrix::from_local(&c, &a, rpb, inner, 2);
            let bb = BlockMatrix::from_local(&c, &b, inner, cpb, 2);
            let prod = ba.multiply(&bb).unwrap().to_local().unwrap();
            let want = a.matmul(&b).unwrap();
            assert!(
                prod.max_abs_diff(&want) < 1e-10 * (1.0 + want.frob_norm()),
                "err {}",
                prod.max_abs_diff(&want)
            );
        });
    }

    #[test]
    fn transpose_matches_local() {
        let c = ctx();
        let a = DenseMatrix::randn(7, 11, &mut SplitMix64::new(1));
        let bm = BlockMatrix::from_local(&c, &a, 3, 4, 2);
        let t = bm.transpose();
        t.validate().unwrap();
        assert!(t.to_local().unwrap().max_abs_diff(&a.transpose()) < 1e-12);
    }

    #[test]
    fn from_coordinate_matches() {
        let c = ctx();
        let cm = CoordinateMatrix::sprand(&c, 25, 13, 80, 3, 9);
        let bm = BlockMatrix::from_coordinate(&cm, 4, 5, 3).unwrap();
        bm.validate().unwrap();
        assert!(bm.to_local().unwrap().max_abs_diff(&cm.to_local().unwrap()) < 1e-12);
    }

    #[test]
    fn dim_mismatches_rejected() {
        let c = ctx();
        let a = DenseMatrix::randn(4, 4, &mut SplitMix64::new(2));
        let b = DenseMatrix::randn(5, 4, &mut SplitMix64::new(3));
        let ba = BlockMatrix::from_local(&c, &a, 2, 2, 2);
        let bb = BlockMatrix::from_local(&c, &b, 2, 2, 2);
        assert!(ba.add(&bb).is_err());
        assert!(ba.multiply(&bb).is_err()); // inner 4 vs 5
    }

    #[test]
    fn validate_catches_bad_blocks() {
        let c = ctx();
        // block claims index outside the grid
        let blocks =
            c.parallelize(vec![((5usize, 0usize), Block::Dense(DenseMatrix::zeros(2, 2)))], 1);
        let bm = BlockMatrix::new(&c, blocks, 2, 2, 4, 4);
        assert!(bm.validate().is_err());
        // wrong shape
        let blocks =
            c.parallelize(vec![((0usize, 0usize), Block::Dense(DenseMatrix::zeros(1, 2)))], 1);
        let bm = BlockMatrix::new(&c, blocks, 2, 2, 4, 4);
        assert!(bm.validate().is_err());
    }

    #[test]
    fn sparse_blocks_survive_block_ops() {
        let c = ctx();
        // 80 entries over 25x13 is ~25% fill globally, so most 4x5
        // blocks land under the sparse threshold
        let cm = CoordinateMatrix::sprand(&c, 25, 13, 60, 3, 11);
        let bm = BlockMatrix::from_coordinate(&cm, 4, 5, 3).unwrap();
        let sparse_blocks = bm
            .blocks
            .aggregate(0usize, |a, (_k, b)| a + b.is_sparse() as usize, |a, b| a + b)
            .unwrap();
        assert!(sparse_blocks > 0, "expected some CSR blocks from sparse input");
        let dense_ref = cm.to_local().unwrap();
        // transpose / scale / add keep values right with sparse blocks
        assert!(bm.transpose().to_local().unwrap().max_abs_diff(&dense_ref.transpose()) < 1e-12);
        assert!(bm.scale(2.0).to_local().unwrap().max_abs_diff(&dense_ref.scale(2.0)) < 1e-12);
        let doubled = bm.add(&bm).unwrap();
        assert!(doubled.to_local().unwrap().max_abs_diff(&dense_ref.scale(2.0)) < 1e-12);
        // densify is value-preserving and purely dense
        let dn = bm.densify();
        assert_eq!(
            dn.blocks
                .aggregate(0usize, |a, (_k, b)| a + b.is_sparse() as usize, |a, b| a + b)
                .unwrap(),
            0
        );
        assert!(dn.to_local().unwrap().max_abs_diff(&dense_ref) < 1e-12);
        // sparsify round-trips dense blocks back to CSR
        let sp = dn.sparsify(1.0);
        assert!(
            sp.blocks
                .aggregate(0usize, |a, (_k, b)| a + b.is_sparse() as usize, |a, b| a + b)
                .unwrap()
                > 0
        );
        assert!(sp.to_local().unwrap().max_abs_diff(&dense_ref) < 1e-12);
        assert_eq!(sp.nnz().unwrap(), bm.nnz().unwrap());
    }

    #[test]
    fn sparse_multiply_matches_dense_and_counts_kernels() {
        let c = ctx();
        let cm_a = CoordinateMatrix::sprand(&c, 18, 10, 40, 2, 21);
        let cm_b = CoordinateMatrix::sprand(&c, 10, 14, 35, 2, 22);
        let ba = BlockMatrix::from_coordinate(&cm_a, 3, 4, 2).unwrap();
        let bb = BlockMatrix::from_coordinate(&cm_b, 4, 5, 2).unwrap();
        let before = c.metrics().spmm_sparse_sparse.load(Ordering::Relaxed)
            + c.metrics().spmm_sparse_dense.load(Ordering::Relaxed)
            + c.metrics().spmm_dense_sparse.load(Ordering::Relaxed);
        let sparse_prod = ba.multiply(&bb).unwrap().to_local().unwrap();
        let after = c.metrics().spmm_sparse_sparse.load(Ordering::Relaxed)
            + c.metrics().spmm_sparse_dense.load(Ordering::Relaxed)
            + c.metrics().spmm_dense_sparse.load(Ordering::Relaxed);
        assert!(after > before, "sparse-aware kernels never dispatched");
        let dense_prod = ba.densify().multiply(&bb.densify()).unwrap().to_local().unwrap();
        assert!(sparse_prod.max_abs_diff(&dense_prod) < 1e-9);
        let want = cm_a
            .to_local()
            .unwrap()
            .matmul(&cm_b.to_local().unwrap())
            .unwrap();
        assert!(sparse_prod.max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn scale_matches() {
        let c = ctx();
        let a = DenseMatrix::randn(6, 6, &mut SplitMix64::new(4));
        let bm = BlockMatrix::from_local(&c, &a, 2, 3, 2);
        assert!(bm.scale(-2.5).to_local().unwrap().max_abs_diff(&a.scale(-2.5)) < 1e-12);
    }
}
