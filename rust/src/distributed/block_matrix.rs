//! `BlockMatrix` (paper §2.3): dense sub-blocks in an RDD keyed by block
//! coordinates. Supports `add`, `multiply` (the shuffle-join the paper's
//! "large linear model parallelism" [4, 9] builds on), `transpose`, and
//! the paper's `validate` helper.

use crate::coordinator::context::Context;
use crate::distributed::coordinate_matrix::{CoordinateMatrix, MatrixEntry};
use crate::error::{Error, Result};
use crate::linalg::matrix::DenseMatrix;
use crate::rdd::Rdd;

/// Block-partitioned distributed matrix.
#[derive(Clone)]
pub struct BlockMatrix {
    /// ((block_row, block_col), block) records.
    pub blocks: Rdd<((usize, usize), DenseMatrix)>,
    /// Rows per (full) block.
    pub rows_per_block: usize,
    /// Cols per (full) block.
    pub cols_per_block: usize,
    /// Total rows.
    pub num_rows: usize,
    /// Total cols.
    pub num_cols: usize,
    ctx: Context,
}

impl BlockMatrix {
    /// Wrap a blocks RDD (callers promise block sizes; `validate()` checks).
    pub fn new(
        ctx: &Context,
        blocks: Rdd<((usize, usize), DenseMatrix)>,
        rows_per_block: usize,
        cols_per_block: usize,
        num_rows: usize,
        num_cols: usize,
    ) -> BlockMatrix {
        BlockMatrix { blocks, rows_per_block, cols_per_block, num_rows, num_cols, ctx: ctx.clone() }
    }

    /// Split a local matrix into blocks.
    pub fn from_local(
        ctx: &Context,
        a: &DenseMatrix,
        rows_per_block: usize,
        cols_per_block: usize,
        num_partitions: usize,
    ) -> BlockMatrix {
        let mut blocks = vec![];
        for bi in 0..a.rows.div_ceil(rows_per_block) {
            for bj in 0..a.cols.div_ceil(cols_per_block) {
                let r0 = bi * rows_per_block;
                let c0 = bj * cols_per_block;
                let nr = rows_per_block.min(a.rows - r0);
                let nc = cols_per_block.min(a.cols - c0);
                blocks.push(((bi, bj), a.block(r0, c0, nr, nc)));
            }
        }
        BlockMatrix::new(
            ctx,
            ctx.parallelize(blocks, num_partitions),
            rows_per_block,
            cols_per_block,
            a.rows,
            a.cols,
        )
    }

    /// From coordinate entries (one shuffle; the paper's
    /// `CoordinateMatrix.toBlockMatrix`).
    pub fn from_coordinate(
        cm: &CoordinateMatrix,
        rows_per_block: usize,
        cols_per_block: usize,
        num_partitions: usize,
    ) -> Result<BlockMatrix> {
        let (nr, nc) = (cm.num_rows as usize, cm.num_cols as usize);
        let rpb = rows_per_block;
        let cpb = cols_per_block;
        let keyed = cm
            .entries
            .map(move |e| (((e.i as usize / rpb), (e.j as usize / cpb)), vec![*e]));
        let grouped = keyed.reduce_by_key(num_partitions.max(1), |a: &Vec<MatrixEntry>, b| {
            let mut v = a.clone();
            v.extend_from_slice(b);
            v
        });
        let blocks = grouped.map(move |((bi, bj), entries)| {
            let (bi, bj) = (*bi, *bj);
            let block_rows = rpb.min(nr - bi * rpb);
            let block_cols = cpb.min(nc - bj * cpb);
            let mut m = DenseMatrix::zeros(block_rows, block_cols);
            for e in entries {
                let li = e.i as usize - bi * rpb;
                let lj = e.j as usize - bj * cpb;
                let cur = m.get(li, lj);
                m.set(li, lj, cur + e.value);
            }
            ((bi, bj), m)
        });
        Ok(BlockMatrix::new(cm.context(), blocks, rpb, cpb, nr, nc))
    }

    /// Owning context.
    pub fn context(&self) -> &Context {
        &self.ctx
    }

    /// Cache the backing blocks.
    pub fn cache(&self) -> BlockMatrix {
        BlockMatrix {
            blocks: self.blocks.clone().cache(),
            rows_per_block: self.rows_per_block,
            cols_per_block: self.cols_per_block,
            num_rows: self.num_rows,
            num_cols: self.num_cols,
            ctx: self.ctx.clone(),
        }
    }

    /// Nonzeros stored inside blocks (explicit zeros excluded, matching
    /// the other formats' accounting).
    pub fn nnz(&self) -> Result<usize> {
        self.blocks.aggregate(
            0usize,
            |a, (_k, m)| a + m.data.iter().filter(|&&x| x != 0.0).count(),
            |a, b| a + b,
        )
    }

    /// Explode blocks into coordinate entries (no shuffle — entries stay
    /// in their block's partition; the reverse of `from_coordinate`).
    pub fn to_coordinate_matrix(&self) -> CoordinateMatrix {
        let (rpb, cpb) = (self.rows_per_block, self.cols_per_block);
        let entries = self.blocks.flat_map(move |((bi, bj), m)| {
            let (r0, c0) = (*bi * rpb, *bj * cpb);
            let mut out = vec![];
            for i in 0..m.rows {
                for j in 0..m.cols {
                    let v = m.get(i, j);
                    if v != 0.0 {
                        out.push(MatrixEntry {
                            i: (r0 + i) as u64,
                            j: (c0 + j) as u64,
                            value: v,
                        });
                    }
                }
            }
            out
        });
        CoordinateMatrix::new(&self.ctx, entries, self.num_rows as u64, self.num_cols as u64)
    }

    /// Regroup into sparse indexed rows (one shuffle, via coordinates).
    pub fn to_indexed_row_matrix(
        &self,
        num_partitions: usize,
    ) -> Result<crate::distributed::indexed_row_matrix::IndexedRowMatrix> {
        self.to_coordinate_matrix().to_indexed_row_matrix(num_partitions)
    }

    /// Regroup into rows, dropping indices (one shuffle).
    pub fn to_row_matrix(
        &self,
        num_partitions: usize,
    ) -> Result<crate::distributed::row_matrix::RowMatrix> {
        Ok(self.to_indexed_row_matrix(num_partitions)?.to_row_matrix())
    }

    /// Block-grid dimensions.
    pub fn grid(&self) -> (usize, usize) {
        (
            self.num_rows.div_ceil(self.rows_per_block),
            self.num_cols.div_ceil(self.cols_per_block),
        )
    }

    /// The paper's `validate()`: checks block indices are in range, block
    /// shapes match their grid slot, and no duplicate indices exist.
    pub fn validate(&self) -> Result<()> {
        let (gr, gc) = self.grid();
        let (rpb, cpb) = (self.rows_per_block, self.cols_per_block);
        let (nr, nc) = (self.num_rows, self.num_cols);
        let issues = self.blocks.map(move |((bi, bj), m)| {
            let (bi, bj) = (*bi, *bj);
            let mut problems: Vec<String> = vec![];
            if bi >= gr || bj >= gc {
                problems.push(format!("block ({bi},{bj}) outside {gr}x{gc} grid"));
            } else {
                let want_r = rpb.min(nr - bi * rpb);
                let want_c = cpb.min(nc - bj * cpb);
                if (m.rows, m.cols) != (want_r, want_c) {
                    problems.push(format!(
                        "block ({bi},{bj}) is {}x{}, expected {want_r}x{want_c}",
                        m.rows, m.cols
                    ));
                }
            }
            ((bi, bj), problems)
        });
        let collected = issues.collect()?;
        let mut seen = std::collections::HashSet::new();
        for ((bi, bj), problems) in collected {
            if let Some(p) = problems.first() {
                return Err(Error::Validation(p.clone()));
            }
            if !seen.insert((bi, bj)) {
                return Err(Error::Validation(format!("duplicate block index ({bi},{bj})")));
            }
        }
        Ok(())
    }

    /// Element-wise add (blocks co-located by key; one shuffle each side).
    pub fn add(&self, other: &BlockMatrix) -> Result<BlockMatrix> {
        if (self.num_rows, self.num_cols) != (other.num_rows, other.num_cols)
            || (self.rows_per_block, self.cols_per_block)
                != (other.rows_per_block, other.cols_per_block)
        {
            return Err(Error::dim(format!(
                "BlockMatrix add: {}x{} ({}x{} blocks) vs {}x{} ({}x{} blocks)",
                self.num_rows,
                self.num_cols,
                self.rows_per_block,
                self.cols_per_block,
                other.num_rows,
                other.num_cols,
                other.rows_per_block,
                other.cols_per_block
            )));
        }
        let parts = self.blocks.num_partitions().max(other.blocks.num_partitions());
        let tagged = self
            .blocks
            .map(|(k, m)| (*k, m.clone()))
            .union(&other.blocks.map(|(k, m)| (*k, m.clone())));
        let summed = tagged.reduce_by_key(parts, |a: &DenseMatrix, b: &DenseMatrix| {
            a.add(b).expect("validated block shapes")
        });
        Ok(BlockMatrix::new(
            &self.ctx,
            summed,
            self.rows_per_block,
            self.cols_per_block,
            self.num_rows,
            self.num_cols,
        ))
    }

    /// Distributed matrix multiply: join on the contraction index k —
    /// map each A(i,k) and B(k,j) to key k, join, emit partial products
    /// keyed (i,j), reduce by sum. (The classic SUMMA-over-shuffle.)
    pub fn multiply(&self, other: &BlockMatrix) -> Result<BlockMatrix> {
        if self.num_cols != other.num_rows || self.cols_per_block != other.rows_per_block {
            return Err(Error::dim(format!(
                "BlockMatrix multiply: inner {} ({}per) vs {} ({}per)",
                self.num_cols, self.cols_per_block, other.num_rows, other.rows_per_block
            )));
        }
        let parts = self.blocks.num_partitions().max(other.blocks.num_partitions());
        let a_by_k = self.blocks.map(|((i, k), m)| (*k, (*i, m.clone())));
        let b_by_k = other.blocks.map(|((k, j), m)| (*k, (*j, m.clone())));
        let joined = a_by_k.join(&b_by_k, parts);
        let partials = joined.map(|(_k, ((i, a), (j, b)))| {
            ((*i, *j), a.matmul(b).expect("inner block dims validated"))
        });
        let reduced = partials.reduce_by_key(parts, |x: &DenseMatrix, y: &DenseMatrix| {
            x.add(y).expect("partial product shapes agree")
        });
        Ok(BlockMatrix::new(
            &self.ctx,
            reduced,
            self.rows_per_block,
            other.cols_per_block,
            self.num_rows,
            other.num_cols,
        ))
    }

    /// Transpose (blocks transpose locally; indices swap).
    pub fn transpose(&self) -> BlockMatrix {
        let blocks = self.blocks.map(|((i, j), m)| ((*j, *i), m.transpose()));
        BlockMatrix::new(
            &self.ctx,
            blocks,
            self.cols_per_block,
            self.rows_per_block,
            self.num_cols,
            self.num_rows,
        )
    }

    /// Scale every block.
    pub fn scale(&self, alpha: f64) -> BlockMatrix {
        let blocks = self.blocks.map(move |(k, m)| (*k, m.scale(alpha)));
        BlockMatrix::new(
            &self.ctx,
            blocks,
            self.rows_per_block,
            self.cols_per_block,
            self.num_rows,
            self.num_cols,
        )
    }

    /// Collect to a local dense matrix (tests / small results).
    pub fn to_local(&self) -> Result<DenseMatrix> {
        let mut out = DenseMatrix::zeros(self.num_rows, self.num_cols);
        for ((bi, bj), m) in self.blocks.collect()? {
            let r0 = bi * self.rows_per_block;
            let c0 = bj * self.cols_per_block;
            for i in 0..m.rows {
                for j in 0..m.cols {
                    let cur = out.get(r0 + i, c0 + j);
                    out.set(r0 + i, c0 + j, cur + m.get(i, j));
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::SplitMix64;

    fn ctx() -> Context {
        Context::local("block_test", 2)
    }

    #[test]
    fn from_local_roundtrip_property() {
        check("blockmatrix to_local == original", 8, |g| {
            let c = ctx();
            let r = 1 + g.int(0, 20);
            let cc = 1 + g.int(0, 20);
            let a = DenseMatrix::randn(r, cc, g.rng());
            let rpb = 1 + g.int(0, 6);
            let cpb = 1 + g.int(0, 6);
            let bm = BlockMatrix::from_local(&c, &a, rpb, cpb, 3);
            bm.validate().unwrap();
            assert!(bm.to_local().unwrap().max_abs_diff(&a) < 1e-12);
        });
    }

    #[test]
    fn add_matches_local_property() {
        check("block add == local add", 6, |g| {
            let c = ctx();
            let r = 1 + g.int(0, 15);
            let cc = 1 + g.int(0, 15);
            let a = DenseMatrix::randn(r, cc, g.rng());
            let b = DenseMatrix::randn(r, cc, g.rng());
            let rpb = 1 + g.int(0, 4);
            let cpb = 1 + g.int(0, 4);
            let ba = BlockMatrix::from_local(&c, &a, rpb, cpb, 2);
            let bb = BlockMatrix::from_local(&c, &b, rpb, cpb, 3);
            let sum = ba.add(&bb).unwrap().to_local().unwrap();
            assert!(sum.max_abs_diff(&a.add(&b).unwrap()) < 1e-12);
        });
    }

    #[test]
    fn multiply_matches_local_property() {
        check("block multiply == local matmul", 6, |g| {
            let c = ctx();
            let m = 1 + g.int(0, 12);
            let k = 1 + g.int(0, 12);
            let n = 1 + g.int(0, 12);
            let a = DenseMatrix::randn(m, k, g.rng());
            let b = DenseMatrix::randn(k, n, g.rng());
            let rpb = 1 + g.int(0, 4);
            let inner = 1 + g.int(0, 4);
            let cpb = 1 + g.int(0, 4);
            let ba = BlockMatrix::from_local(&c, &a, rpb, inner, 2);
            let bb = BlockMatrix::from_local(&c, &b, inner, cpb, 2);
            let prod = ba.multiply(&bb).unwrap().to_local().unwrap();
            let want = a.matmul(&b).unwrap();
            assert!(
                prod.max_abs_diff(&want) < 1e-10 * (1.0 + want.frob_norm()),
                "err {}",
                prod.max_abs_diff(&want)
            );
        });
    }

    #[test]
    fn transpose_matches_local() {
        let c = ctx();
        let a = DenseMatrix::randn(7, 11, &mut SplitMix64::new(1));
        let bm = BlockMatrix::from_local(&c, &a, 3, 4, 2);
        let t = bm.transpose();
        t.validate().unwrap();
        assert!(t.to_local().unwrap().max_abs_diff(&a.transpose()) < 1e-12);
    }

    #[test]
    fn from_coordinate_matches() {
        let c = ctx();
        let cm = CoordinateMatrix::sprand(&c, 25, 13, 80, 3, 9);
        let bm = BlockMatrix::from_coordinate(&cm, 4, 5, 3).unwrap();
        bm.validate().unwrap();
        assert!(bm.to_local().unwrap().max_abs_diff(&cm.to_local().unwrap()) < 1e-12);
    }

    #[test]
    fn dim_mismatches_rejected() {
        let c = ctx();
        let a = DenseMatrix::randn(4, 4, &mut SplitMix64::new(2));
        let b = DenseMatrix::randn(5, 4, &mut SplitMix64::new(3));
        let ba = BlockMatrix::from_local(&c, &a, 2, 2, 2);
        let bb = BlockMatrix::from_local(&c, &b, 2, 2, 2);
        assert!(ba.add(&bb).is_err());
        assert!(ba.multiply(&bb).is_err()); // inner 4 vs 5
    }

    #[test]
    fn validate_catches_bad_blocks() {
        let c = ctx();
        // block claims index outside the grid
        let blocks = c.parallelize(vec![((5usize, 0usize), DenseMatrix::zeros(2, 2))], 1);
        let bm = BlockMatrix::new(&c, blocks, 2, 2, 4, 4);
        assert!(bm.validate().is_err());
        // wrong shape
        let blocks = c.parallelize(vec![((0usize, 0usize), DenseMatrix::zeros(1, 2))], 1);
        let bm = BlockMatrix::new(&c, blocks, 2, 2, 4, 4);
        assert!(bm.validate().is_err());
    }

    #[test]
    fn scale_matches() {
        let c = ctx();
        let a = DenseMatrix::randn(6, 6, &mut SplitMix64::new(4));
        let bm = BlockMatrix::from_local(&c, &a, 2, 3, 2);
        assert!(bm.scale(-2.5).to_local().unwrap().max_abs_diff(&a.scale(-2.5)) < 1e-12);
    }
}
