//! `IndexedRowMatrix` (paper §2.1): a RowMatrix whose rows carry
//! meaningful `u64` indices — the bridge between coordinate and row
//! formats.

use std::sync::{Arc, OnceLock};

use crate::coordinator::context::Context;
use crate::distributed::block_matrix::BlockMatrix;
use crate::distributed::coordinate_matrix::{CoordinateMatrix, MatrixEntry};
use crate::distributed::row::Row;
use crate::distributed::row_matrix::RowMatrix;
use crate::error::{Error, Result};
use crate::rdd::Rdd;

/// Row-indexed distributed matrix.
#[derive(Clone)]
pub struct IndexedRowMatrix {
    /// (row index, row) records.
    pub rows: Rdd<(u64, Row)>,
    ctx: Context,
    n_cols: Arc<OnceLock<usize>>,
    n_rows: Arc<OnceLock<u64>>,
}

impl IndexedRowMatrix {
    /// Wrap an RDD of indexed rows.
    pub fn new(ctx: &Context, rows: Rdd<(u64, Row)>, n_cols: Option<usize>) -> IndexedRowMatrix {
        let cell = OnceLock::new();
        if let Some(n) = n_cols {
            let _ = cell.set(n);
        }
        IndexedRowMatrix {
            rows,
            ctx: ctx.clone(),
            n_cols: Arc::new(cell),
            n_rows: Arc::new(OnceLock::new()),
        }
    }

    /// Owning context.
    pub fn context(&self) -> &Context {
        &self.ctx
    }

    /// Cache the backing rows.
    pub fn cache(&self) -> IndexedRowMatrix {
        IndexedRowMatrix {
            rows: self.rows.clone().cache(),
            ctx: self.ctx.clone(),
            n_cols: Arc::clone(&self.n_cols),
            n_rows: Arc::clone(&self.n_rows),
        }
    }

    /// Total stored nonzeros.
    pub fn nnz(&self) -> Result<usize> {
        self.rows.aggregate(0usize, |a, (_, r)| a + r.nnz(), |a, b| a + b)
    }

    /// Column count (declared or scanned; cached — iterative operator
    /// consumers call this every pass).
    pub fn num_cols(&self) -> Result<usize> {
        if let Some(&n) = self.n_cols.get() {
            return Ok(n);
        }
        let n = self
            .rows
            .aggregate(0usize, |acc, (_, r)| acc.max(r.len()), |a, b| a.max(b))?;
        if n == 0 {
            return Err(Error::InvalidArgument("empty IndexedRowMatrix".into()));
        }
        Ok(*self.n_cols.get_or_init(|| n))
    }

    /// Logical row count: max index + 1 (MLlib semantics — indices may be
    /// sparse). Cached after the first cluster pass.
    pub fn num_rows(&self) -> Result<u64> {
        if let Some(&n) = self.n_rows.get() {
            return Ok(n);
        }
        let max_idx = self
            .rows
            .aggregate(None::<u64>, |acc, (i, _)| Some(acc.map_or(*i, |a| a.max(*i))), |a, b| {
                match (a, b) {
                    (None, x) | (x, None) => x,
                    (Some(a), Some(b)) => Some(a.max(b)),
                }
            })?;
        let n = max_idx
            .map(|i| i + 1)
            .ok_or_else(|| Error::InvalidArgument("empty IndexedRowMatrix".into()))?;
        Ok(*self.n_rows.get_or_init(|| n))
    }

    /// Drop the indices (paper: `toRowMatrix`).
    pub fn to_row_matrix(&self) -> RowMatrix {
        let rdd = self.rows.map(|(_, r)| r.clone());
        RowMatrix::new(&self.ctx, rdd, self.n_cols.get().copied())
    }

    /// Explode into coordinate entries (`toCoordinateMatrix`).
    pub fn to_coordinate_matrix(&self) -> Result<CoordinateMatrix> {
        let n_cols = self.num_cols()? as u64;
        let n_rows = self.num_rows()?;
        let entries = self.rows.flat_map(|(i, r)| {
            let i = *i;
            match r {
                Row::Dense(v) => v
                    .iter()
                    .enumerate()
                    .filter(|(_, &x)| x != 0.0)
                    .map(|(j, &x)| MatrixEntry { i, j: j as u64, value: x })
                    .collect(),
                Row::Sparse(s) => s
                    .indices
                    .iter()
                    .zip(&s.values)
                    .map(|(&j, &x)| MatrixEntry { i, j: j as u64, value: x })
                    .collect(),
            }
        });
        Ok(CoordinateMatrix::new(&self.ctx, entries, n_rows, n_cols))
    }

    /// Re-block into a [`BlockMatrix`] (one shuffle, via coordinates).
    pub fn to_block_matrix(
        &self,
        rows_per_block: usize,
        cols_per_block: usize,
        num_partitions: usize,
    ) -> Result<BlockMatrix> {
        self.to_coordinate_matrix()?
            .to_block_matrix(rows_per_block, cols_per_block, num_partitions)
    }

    /// Multiply by a small local matrix (index-preserving).
    pub fn multiply_local(&self, b: &crate::linalg::matrix::DenseMatrix) -> Result<IndexedRowMatrix> {
        let n = self.num_cols()?;
        crate::ensure_dims!(b.rows, n, "indexed multiply_local dims");
        let k = b.cols;
        let bb = self.ctx.broadcast(b.clone());
        let rdd = self.rows.map(move |(i, r)| {
            let b = bb.value();
            let mut out = vec![0.0; k];
            let dense = r.to_dense();
            for (ii, &x) in dense.iter().enumerate() {
                if x != 0.0 {
                    for j in 0..k {
                        out[j] += x * b.get(ii, j);
                    }
                }
            }
            (*i, Row::Dense(out))
        });
        Ok(IndexedRowMatrix::new(&self.ctx, rdd, Some(k)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::DenseMatrix;
    use crate::util::rng::SplitMix64;

    fn ctx() -> Context {
        Context::local("irm_test", 2)
    }

    fn sample(c: &Context) -> IndexedRowMatrix {
        let rows = vec![
            (0u64, Row::Dense(vec![1.0, 0.0, 2.0])),
            (2u64, Row::Dense(vec![0.0, 3.0, 0.0])),
            (5u64, Row::Dense(vec![4.0, 0.0, 0.0])),
        ];
        IndexedRowMatrix::new(c, c.parallelize(rows, 2), Some(3))
    }

    #[test]
    fn dims_respect_sparse_indices() {
        let c = ctx();
        let m = sample(&c);
        assert_eq!(m.num_rows().unwrap(), 6); // max index 5 + 1
        assert_eq!(m.num_cols().unwrap(), 3);
    }

    #[test]
    fn to_row_matrix_drops_indices() {
        let c = ctx();
        let m = sample(&c).to_row_matrix();
        assert_eq!(m.num_rows().unwrap(), 3);
        assert_eq!(m.nnz().unwrap(), 4);
    }

    #[test]
    fn to_coordinate_roundtrip() {
        let c = ctx();
        let cm = sample(&c).to_coordinate_matrix().unwrap();
        assert_eq!(cm.num_rows, 6);
        assert_eq!(cm.num_cols, 3);
        let mut entries = cm.entries.collect().unwrap();
        entries.sort_by_key(|e| (e.i, e.j));
        assert_eq!(entries.len(), 4);
        assert_eq!((entries[0].i, entries[0].j, entries[0].value), (0, 0, 1.0));
        assert_eq!((entries[3].i, entries[3].j, entries[3].value), (5, 0, 4.0));
    }

    #[test]
    fn multiply_preserves_indices() {
        let c = ctx();
        let m = sample(&c);
        let b = DenseMatrix::randn(3, 2, &mut SplitMix64::new(1));
        let prod = m.multiply_local(&b).unwrap();
        let mut rows = prod.rows.collect().unwrap();
        rows.sort_by_key(|(i, _)| *i);
        assert_eq!(rows.iter().map(|(i, _)| *i).collect::<Vec<_>>(), vec![0, 2, 5]);
        // row 2 was [0,3,0] -> product = 3 * b.row(1)
        let r2 = rows[1].1.to_dense();
        assert!((r2[0] - 3.0 * b.get(1, 0)).abs() < 1e-12);
        assert!((r2[1] - 3.0 * b.get(1, 1)).abs() < 1e-12);
    }
}
