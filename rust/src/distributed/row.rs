//! `Row`: one matrix row, dense or sparse — the paper's §2.4 local-vector
//! pair, used as the record type of `RowMatrix`.

use crate::linalg::sparse::SparseVector;
use crate::linalg::matrix::DenseMatrix;
use crate::linalg::vector::Vector;

/// A single row with dense or sparse storage.
#[derive(Debug, Clone, PartialEq)]
pub enum Row {
    /// Dense values.
    Dense(Vec<f64>),
    /// Sparse (sorted indices + values).
    Sparse(SparseVector),
}

impl Row {
    /// Logical length.
    pub fn len(&self) -> usize {
        match self {
            Row::Dense(v) => v.len(),
            Row::Sparse(s) => s.size,
        }
    }

    /// True when length 0.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Stored nonzeros (== len for dense).
    pub fn nnz(&self) -> usize {
        match self {
            Row::Dense(v) => v.iter().filter(|&&x| x != 0.0).count(),
            Row::Sparse(s) => s.nnz(),
        }
    }

    /// Dot with a dense vector.
    pub fn dot(&self, x: &Vector) -> f64 {
        match self {
            Row::Dense(v) => crate::linalg::vector::blas_dot(v, x.as_slice()),
            Row::Sparse(s) => s.dot_dense(x),
        }
    }

    /// Densify.
    pub fn to_dense(&self) -> Vec<f64> {
        match self {
            Row::Dense(v) => v.clone(),
            Row::Sparse(s) => s.to_dense().0,
        }
    }

    /// Scatter `alpha * row` into an accumulator (Aᵀy inner loop).
    pub fn axpy_into(&self, alpha: f64, acc: &mut [f64]) {
        if alpha == 0.0 {
            return;
        }
        match self {
            Row::Dense(v) => {
                for (a, &x) in acc.iter_mut().zip(v) {
                    *a += alpha * x;
                }
            }
            Row::Sparse(s) => {
                for (&i, &x) in s.indices.iter().zip(&s.values) {
                    acc[i as usize] += alpha * x;
                }
            }
        }
    }

    /// Rank-1 update of an upper-triangular Gram accumulator:
    /// `G[i][j] += row[i]*row[j]` for i <= j (both nonzero).
    pub fn gram_into(&self, g: &mut DenseMatrix) {
        let n = g.cols;
        match self {
            Row::Dense(v) => {
                for i in 0..n {
                    let ri = v[i];
                    if ri == 0.0 {
                        continue;
                    }
                    let row = &mut g.data[i * n..(i + 1) * n];
                    for j in i..n {
                        row[j] += ri * v[j];
                    }
                }
            }
            Row::Sparse(s) => {
                for (a, (&ia, &va)) in s.indices.iter().zip(&s.values).enumerate() {
                    for (&ib, &vb) in s.indices[a..].iter().zip(&s.values[a..]) {
                        g.data[ia as usize * n + ib as usize] += va * vb;
                    }
                }
            }
        }
    }
}

/// Build a dense block from a slice of rows (executor-side adapter for
/// the XLA ops; sparse rows densify here).
pub fn rows_to_block(rows: &[Row], n_cols: usize) -> DenseMatrix {
    let mut m = DenseMatrix::zeros(rows.len(), n_cols);
    for (i, r) in rows.iter().enumerate() {
        match r {
            Row::Dense(v) => m.row_mut(i)[..v.len()].copy_from_slice(v),
            Row::Sparse(s) => {
                let out = m.row_mut(i);
                for (&j, &x) in s.indices.iter().zip(&s.values) {
                    out[j as usize] = x;
                }
            }
        }
    }
    m
}

impl crate::rdd::memory::SizeOf for Row {
    fn heap_bytes(&self) -> usize {
        use crate::rdd::memory::SizeOf;
        match self {
            Row::Dense(v) => v.heap_bytes(),
            Row::Sparse(s) => s.heap_bytes(),
        }
    }
}

impl crate::rdd::memory::Spill for Row {
    fn encode(&self, out: &mut Vec<u8>) {
        use crate::rdd::memory::Spill;
        match self {
            Row::Dense(v) => {
                out.push(0);
                v.encode(out);
            }
            Row::Sparse(s) => {
                out.push(1);
                s.encode(out);
            }
        }
    }

    fn decode(src: &mut &[u8]) -> crate::error::Result<Self> {
        use crate::rdd::memory::Spill;
        match u8::decode(src)? {
            0 => Vec::<f64>::decode(src).map(Row::Dense),
            1 => SparseVector::decode(src).map(Row::Sparse),
            _ => Err(crate::error::Error::msg("spill decode: invalid Row tag")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_allclose, check};

    fn sparse(xs: &[f64]) -> Row {
        Row::Sparse(SparseVector::from_dense(xs))
    }

    #[test]
    fn len_nnz_dot() {
        let d = Row::Dense(vec![1.0, 0.0, 2.0]);
        let s = sparse(&[1.0, 0.0, 2.0]);
        assert_eq!(d.len(), 3);
        assert_eq!(s.len(), 3);
        assert_eq!(d.nnz(), 2);
        assert_eq!(s.nnz(), 2);
        let x = Vector::from(&[3.0, 9.0, 0.5]);
        assert_eq!(d.dot(&x), 4.0);
        assert_eq!(s.dot(&x), 4.0);
    }

    #[test]
    fn axpy_dense_sparse_agree_property() {
        check("axpy_into dense == sparse", 25, |g| {
            let n = g.int(1, 20);
            let xs: Vec<f64> =
                (0..n).map(|_| if g.bool(0.5) { g.normal() } else { 0.0 }).collect();
            let alpha = g.normal();
            let mut acc1 = vec![0.5; n];
            let mut acc2 = vec![0.5; n];
            Row::Dense(xs.clone()).axpy_into(alpha, &mut acc1);
            sparse(&xs).axpy_into(alpha, &mut acc2);
            assert_allclose(&acc1, &acc2, 1e-12, "axpy");
        });
    }

    #[test]
    fn gram_dense_sparse_agree_property() {
        check("gram_into dense == sparse", 25, |g| {
            let n = g.int(1, 12);
            let xs: Vec<f64> =
                (0..n).map(|_| if g.bool(0.6) { g.normal() } else { 0.0 }).collect();
            let mut g1 = DenseMatrix::zeros(n, n);
            let mut g2 = DenseMatrix::zeros(n, n);
            Row::Dense(xs.clone()).gram_into(&mut g1);
            sparse(&xs).gram_into(&mut g2);
            assert_allclose(&g1.data, &g2.data, 1e-12, "gram upper");
        });
    }

    #[test]
    fn rows_to_block_mixes_representations() {
        let rows = vec![Row::Dense(vec![1.0, 2.0, 0.0]), sparse(&[0.0, 0.0, 3.0])];
        let b = rows_to_block(&rows, 3);
        assert_eq!(b.row(0), &[1.0, 2.0, 0.0]);
        assert_eq!(b.row(1), &[0.0, 0.0, 3.0]);
    }
}
