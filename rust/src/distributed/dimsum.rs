//! Column cosine similarities: exact (via the Gram matrix) and DIMSUM
//! (Dimension-Independent Matrix Square using MapReduce — paper §3.4,
//! refs [10, 11], by the paper's first author).
//!
//! DIMSUM's idea: when computing AᵀA for similarity, rows with large
//! norms dominate communication. Sampling each co-occurrence (i,j) in a
//! row with probability min(1, γ / (‖cᵢ‖‖cⱼ‖)) and scaling keeps the
//! estimate unbiased while bounding shuffle size *independently of the
//! matrix dimension*. γ = 4 log(n)/ε² gives ε-accurate similarities
//! w.h.p.; callers pass a `threshold` that trades accuracy for traffic.

use crate::distributed::row::Row;
use crate::distributed::row_matrix::{RowMatrix, TREE_FANIN};
use crate::error::{Error, Result};
use crate::linalg::matrix::DenseMatrix;
use crate::util::rng::SplitMix64;

/// Exact cosine similarities: normalize the Gram matrix.
pub fn similarities_exact(a: &RowMatrix) -> Result<DenseMatrix> {
    let g = a.gram()?;
    let n = g.rows;
    let norms: Vec<f64> = (0..n).map(|i| g.get(i, i).max(0.0).sqrt()).collect();
    let mut s = DenseMatrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let d = norms[i] * norms[j];
            s.set(i, j, if d > 1e-300 { g.get(i, j) / d } else { 0.0 });
        }
    }
    Ok(s)
}

/// DIMSUM-sampled cosine similarities. `threshold` ∈ (0, 1]: similarities
/// above it are estimated within ~20% w.h.p.; smaller thresholds sample
/// more. Uses the paper's γ = 10·log(n)/threshold oversampling constant.
pub fn similarities_dimsum(a: &RowMatrix, threshold: f64) -> Result<DenseMatrix> {
    if !(0.0 < threshold && threshold <= 1.0) {
        return Err(Error::InvalidArgument(format!(
            "dimsum threshold must be in (0,1], got {threshold}"
        )));
    }
    let n = a.num_cols()?;
    // column norms from one stats pass
    let stats = a.column_stats()?;
    let norms: Vec<f64> = stats
        .cols
        .iter()
        .map(|c| {
            // E[x²]·n ⇒ ‖c‖² = m2 + n·mean²  (un-centered second moment)
            let m = c.n as f64;
            (c.m2 + m * c.mean * c.mean).max(0.0).sqrt()
        })
        .collect();
    let gamma = (10.0 * (n.max(2) as f64).ln() / threshold).max(1.0);
    let bnorms = a.context().broadcast(norms.clone());
    let sampled = a.rows.map_partitions_with_index(move |p, rows| {
        let norms = bnorms.value();
        let mut rng = SplitMix64::new(0xD1_5C_00 + p as u64);
        let mut acc = DenseMatrix::zeros(n, n);
        for row in rows {
            // materialize the nonzeros once
            let entries: Vec<(usize, f64)> = match row {
                Row::Dense(v) => v
                    .iter()
                    .enumerate()
                    .filter(|(_, &x)| x != 0.0)
                    .map(|(i, &x)| (i, x))
                    .collect(),
                Row::Sparse(s) => s
                    .indices
                    .iter()
                    .zip(&s.values)
                    .map(|(&i, &x)| (i as usize, x))
                    .collect(),
            };
            for (ai, &(i, xi)) in entries.iter().enumerate() {
                for &(j, xj) in &entries[ai..] {
                    let denom = (norms[i] * norms[j]).max(1e-300);
                    let p_keep = (gamma / denom).min(1.0);
                    if rng.bernoulli(p_keep) {
                        // unbiased: contribute x_i x_j / p_keep
                        acc.data[i * n + j] += xi * xj / p_keep;
                    }
                }
            }
        }
        vec![acc]
    });
    let g_est = sampled.tree_aggregate(
        DenseMatrix::zeros(n, n),
        |acc, m| acc.add(m).expect("shapes"),
        |a, b| a.add(&b).expect("shapes"),
        TREE_FANIN,
    )?;
    // normalize to cosine similarities
    let mut s = DenseMatrix::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            let d = norms[i] * norms[j];
            let v = if d > 1e-300 { g_est.get(i, j) / d } else { 0.0 };
            s.set(i, j, v);
            s.set(j, i, v);
        }
    }
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::Context;

    fn ctx() -> Context {
        Context::local("dimsum_test", 2)
    }

    fn random_matrix(m: usize, n: usize, seed: u64) -> DenseMatrix {
        DenseMatrix::randn(m, n, &mut SplitMix64::new(seed))
    }

    #[test]
    fn exact_diagonal_is_one() {
        let c = ctx();
        let a = random_matrix(50, 6, 1);
        let dm = RowMatrix::from_local(&c, &a, 3);
        let s = similarities_exact(&dm).unwrap();
        for i in 0..6 {
            assert!((s.get(i, i) - 1.0).abs() < 1e-10, "diag {i}: {}", s.get(i, i));
        }
        // symmetric, bounded
        for i in 0..6 {
            for j in 0..6 {
                assert!((s.get(i, j) - s.get(j, i)).abs() < 1e-10);
                assert!(s.get(i, j).abs() <= 1.0 + 1e-10);
            }
        }
    }

    #[test]
    fn exact_identical_columns_similarity_one() {
        let c = ctx();
        let mut a = random_matrix(30, 4, 2);
        for i in 0..30 {
            let v = a.get(i, 0);
            a.set(i, 1, v);
        }
        let dm = RowMatrix::from_local(&c, &a, 2);
        let s = similarities_exact(&dm).unwrap();
        assert!((s.get(0, 1) - 1.0).abs() < 1e-10);
    }

    #[test]
    fn dimsum_approximates_exact() {
        let c = ctx();
        let a = random_matrix(300, 8, 3);
        let dm = RowMatrix::from_local(&c, &a, 4);
        let exact = similarities_exact(&dm).unwrap();
        let approx = similarities_dimsum(&dm, 0.08).unwrap();
        // high-similarity entries within the DIMSUM guarantee band
        // (threshold 0.08 => gamma ~ 260, keep-probability ~0.9: sampling is
        // active but estimator sd ~0.04, so the 0.2 band is ~5 sigma)
        for i in 0..8 {
            for j in 0..8 {
                let e = exact.get(i, j);
                if e.abs() > 0.5 {
                    assert!(
                        (approx.get(i, j) - e).abs() < 0.2,
                        "({i},{j}): exact {e} approx {}",
                        approx.get(i, j)
                    );
                }
            }
        }
    }

    #[test]
    fn dimsum_with_gamma_saturated_is_exact() {
        // threshold tiny -> p_keep = 1 everywhere -> estimator is exact
        let c = ctx();
        let a = random_matrix(40, 5, 4);
        let dm = RowMatrix::from_local(&c, &a, 2);
        let exact = similarities_exact(&dm).unwrap();
        let approx = similarities_dimsum(&dm, 1e-6).unwrap();
        assert!(exact.max_abs_diff(&approx) < 1e-9);
    }

    #[test]
    fn bad_threshold_rejected() {
        let c = ctx();
        let a = random_matrix(10, 3, 5);
        let dm = RowMatrix::from_local(&c, &a, 2);
        assert!(similarities_dimsum(&dm, 0.0).is_err());
        assert!(similarities_dimsum(&dm, 1.5).is_err());
    }
}
