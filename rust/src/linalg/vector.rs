//! Dense vector type + the vector-space operations the driver performs
//! locally (the "vector operations" half of the paper's core split).

use crate::error::{Error, Result};

/// A dense `f64` vector. Thin newtype over `Vec<f64>` so the driver-side
/// algebra reads like the math in the paper.
#[derive(Debug, Clone, PartialEq)]
pub struct Vector(pub Vec<f64>);

impl Vector {
    /// All zeros.
    pub fn zeros(n: usize) -> Vector {
        Vector(vec![0.0; n])
    }

    /// All ones.
    pub fn ones(n: usize) -> Vector {
        Vector(vec![1.0; n])
    }

    /// From a slice.
    pub fn from(xs: &[f64]) -> Vector {
        Vector(xs.to_vec())
    }

    /// Length.
    pub fn len(&self) -> usize {
        self.0.len()
    }

    /// Is empty.
    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    /// Borrow as slice.
    pub fn as_slice(&self) -> &[f64] {
        &self.0
    }

    /// Dot product.
    pub fn dot(&self, o: &Vector) -> f64 {
        debug_assert_eq!(self.len(), o.len());
        blas_dot(&self.0, &o.0)
    }

    /// Euclidean norm.
    pub fn norm2(&self) -> f64 {
        self.dot(self).sqrt()
    }

    /// L1 norm.
    pub fn norm1(&self) -> f64 {
        self.0.iter().map(|x| x.abs()).sum()
    }

    /// Infinity norm.
    pub fn norm_inf(&self) -> f64 {
        self.0.iter().fold(0.0, |m, x| m.max(x.abs()))
    }

    /// self += alpha * other (BLAS axpy).
    pub fn axpy(&mut self, alpha: f64, o: &Vector) {
        debug_assert_eq!(self.len(), o.len());
        for (a, b) in self.0.iter_mut().zip(o.0.iter()) {
            *a += alpha * b;
        }
    }

    /// self *= alpha (BLAS scal).
    pub fn scale_mut(&mut self, alpha: f64) {
        for a in &mut self.0 {
            *a *= alpha;
        }
    }

    /// alpha * self (allocating).
    pub fn scale(&self, alpha: f64) -> Vector {
        Vector(self.0.iter().map(|x| alpha * x).collect())
    }

    /// self + other.
    pub fn add(&self, o: &Vector) -> Vector {
        debug_assert_eq!(self.len(), o.len());
        Vector(self.0.iter().zip(o.0.iter()).map(|(a, b)| a + b).collect())
    }

    /// self - other.
    pub fn sub(&self, o: &Vector) -> Vector {
        debug_assert_eq!(self.len(), o.len());
        Vector(self.0.iter().zip(o.0.iter()).map(|(a, b)| a - b).collect())
    }

    /// Element-wise product (Hadamard).
    pub fn hadamard(&self, o: &Vector) -> Vector {
        debug_assert_eq!(self.len(), o.len());
        Vector(self.0.iter().zip(o.0.iter()).map(|(a, b)| a * b).collect())
    }

    /// Linear combination a*x + b*y (one pass; the accelerated-descent
    /// inner update).
    pub fn lincomb(a: f64, x: &Vector, b: f64, y: &Vector) -> Vector {
        debug_assert_eq!(x.len(), y.len());
        Vector(
            x.0.iter()
                .zip(y.0.iter())
                .map(|(xi, yi)| a * xi + b * yi)
                .collect(),
        )
    }

    /// Normalize to unit 2-norm in place; errors on (near-)zero vectors.
    pub fn normalize_mut(&mut self) -> Result<f64> {
        let n = self.norm2();
        if n < 1e-300 {
            return Err(Error::InvalidArgument("cannot normalize zero vector".into()));
        }
        self.scale_mut(1.0 / n);
        Ok(n)
    }

    /// Convert to f32 (for the XLA runtime path).
    pub fn to_f32(&self) -> Vec<f32> {
        self.0.iter().map(|&x| x as f32).collect()
    }

    /// From f32 (results coming back from the XLA runtime).
    pub fn from_f32(xs: &[f32]) -> Vector {
        Vector(xs.iter().map(|&x| x as f64).collect())
    }
}

impl std::ops::Index<usize> for Vector {
    type Output = f64;
    fn index(&self, i: usize) -> &f64 {
        &self.0[i]
    }
}

impl std::ops::IndexMut<usize> for Vector {
    fn index_mut(&mut self, i: usize) -> &mut f64 {
        &mut self.0[i]
    }
}

/// Unrolled dot product — the single hottest driver-side primitive (every
/// Lanczos orthogonalization and every L-BFGS two-loop pass is dots).
/// 4-way unrolling gives the compiler independent accumulator chains.
pub fn blas_dot(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

impl crate::rdd::memory::SizeOf for Vector {
    fn heap_bytes(&self) -> usize {
        crate::rdd::memory::SizeOf::heap_bytes(&self.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_close, check};

    #[test]
    fn dot_and_norms() {
        let v = Vector::from(&[3.0, 4.0]);
        assert_close(v.norm2(), 5.0, 1e-15, "norm2");
        assert_close(v.norm1(), 7.0, 1e-15, "norm1");
        assert_close(v.norm_inf(), 4.0, 1e-15, "norm_inf");
        assert_close(v.dot(&v), 25.0, 1e-15, "dot");
    }

    #[test]
    fn axpy_scale_add_sub() {
        let mut a = Vector::from(&[1.0, 2.0]);
        let b = Vector::from(&[10.0, 20.0]);
        a.axpy(0.5, &b);
        assert_eq!(a.0, vec![6.0, 12.0]);
        assert_eq!(a.scale(2.0).0, vec![12.0, 24.0]);
        assert_eq!(a.add(&b).0, vec![16.0, 32.0]);
        assert_eq!(a.sub(&b).0, vec![-4.0, -8.0]);
        assert_eq!(a.hadamard(&b).0, vec![60.0, 240.0]);
    }

    #[test]
    fn lincomb_matches_manual() {
        let x = Vector::from(&[1.0, -1.0, 2.0]);
        let y = Vector::from(&[0.5, 3.0, -2.0]);
        let z = Vector::lincomb(2.0, &x, -1.0, &y);
        assert_eq!(z.0, vec![1.5, -5.0, 6.0]);
    }

    #[test]
    fn normalize_unit_and_zero() {
        let mut v = Vector::from(&[0.0, 3.0, 4.0]);
        let n = v.normalize_mut().unwrap();
        assert_close(n, 5.0, 1e-15, "returned norm");
        assert_close(v.norm2(), 1.0, 1e-12, "unit");
        let mut z = Vector::zeros(3);
        assert!(z.normalize_mut().is_err());
    }

    #[test]
    fn blas_dot_matches_naive_property() {
        check("blas_dot == naive dot", 40, |g| {
            let xs = g.vec_f64(0, 200);
            let ys: Vec<f64> = xs.iter().map(|x| x * 0.5 + g.normal()).collect();
            let naive: f64 = xs.iter().zip(&ys).map(|(a, b)| a * b).sum();
            assert_close(blas_dot(&xs, &ys), naive, 1e-10, "dot");
        });
    }

    #[test]
    fn f32_roundtrip() {
        let v = Vector::from(&[1.5, -2.25, 0.0]);
        let back = Vector::from_f32(&v.to_f32());
        assert_eq!(v, back);
    }
}
