//! Row-major dense matrix — the local matrix type (paper §2.4) and the
//! in-memory form of one `RowMatrix` partition / one `BlockMatrix` block.

use crate::error::{Error, Result};
use crate::linalg::vector::{blas_dot, Vector};
use crate::util::rng::SplitMix64;

/// Dense row-major `f64` matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct DenseMatrix {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    /// Row-major storage, `rows * cols` long.
    pub data: Vec<f64>,
}

impl DenseMatrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> DenseMatrix {
        DenseMatrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity (or rectangular eye).
    pub fn eye(n: usize) -> DenseMatrix {
        let mut m = DenseMatrix::zeros(n, n);
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m
    }

    /// From row-major data.
    pub fn from_row_major(rows: usize, cols: usize, data: Vec<f64>) -> Result<DenseMatrix> {
        if data.len() != rows * cols {
            return Err(Error::dim(format!(
                "from_row_major: {}x{} needs {} values, got {}",
                rows,
                cols,
                rows * cols,
                data.len()
            )));
        }
        Ok(DenseMatrix { rows, cols, data })
    }

    /// From a list of rows.
    pub fn from_rows(rows: &[Vec<f64>]) -> Result<DenseMatrix> {
        if rows.is_empty() {
            return Ok(DenseMatrix::zeros(0, 0));
        }
        let cols = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * cols);
        for (i, r) in rows.iter().enumerate() {
            if r.len() != cols {
                return Err(Error::dim(format!("row {i} has len {} != {cols}", r.len())));
            }
            data.extend_from_slice(r);
        }
        Ok(DenseMatrix { rows: rows.len(), cols, data })
    }

    /// i.i.d. standard normal entries (deterministic under seed).
    pub fn randn(rows: usize, cols: usize, rng: &mut SplitMix64) -> DenseMatrix {
        DenseMatrix {
            rows,
            cols,
            data: (0..rows * cols).map(|_| rng.normal()).collect(),
        }
    }

    /// Element access.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    /// Element write.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    /// Borrow row i as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row i.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Copy column j out.
    pub fn col(&self, j: usize) -> Vector {
        Vector((0..self.rows).map(|i| self.get(i, j)).collect())
    }

    /// Transpose (allocating).
    pub fn transpose(&self) -> DenseMatrix {
        let mut t = DenseMatrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.set(j, i, self.get(i, j));
            }
        }
        t
    }

    /// Matrix–vector product `A x`.
    pub fn matvec(&self, x: &Vector) -> Result<Vector> {
        crate::ensure_dims!(self.cols, x.len(), "matvec A.cols vs x.len");
        Ok(Vector(
            (0..self.rows).map(|i| blas_dot(self.row(i), x.as_slice())).collect(),
        ))
    }

    /// Transposed matrix–vector product `Aᵀ y` (single pass over rows —
    /// this is the executor-side op in gramvec).
    pub fn tmatvec(&self, y: &Vector) -> Result<Vector> {
        crate::ensure_dims!(self.rows, y.len(), "tmatvec A.rows vs y.len");
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            let yi = y[i];
            if yi == 0.0 {
                continue;
            }
            for (o, &a) in out.iter_mut().zip(self.row(i)) {
                *o += yi * a;
            }
        }
        Ok(Vector(out))
    }

    /// Gram matrix `AᵀA` (n×n, symmetric; only upper triangle computed
    /// then mirrored).
    pub fn gram(&self) -> DenseMatrix {
        let n = self.cols;
        let mut g = DenseMatrix::zeros(n, n);
        for r in 0..self.rows {
            let row = self.row(r);
            for i in 0..n {
                let ri = row[i];
                if ri == 0.0 {
                    continue;
                }
                let gi = &mut g.data[i * n..(i + 1) * n];
                for j in i..n {
                    gi[j] += ri * row[j];
                }
            }
        }
        for i in 0..n {
            for j in 0..i {
                g.data[i * n + j] = g.data[j * n + i];
            }
        }
        g
    }

    /// Matrix product via the default blocked kernel (see `blas::level3`).
    pub fn matmul(&self, o: &DenseMatrix) -> Result<DenseMatrix> {
        crate::ensure_dims!(self.cols, o.rows, "matmul inner dims");
        Ok(crate::linalg::blas::level3::gemm_blocked(self, o))
    }

    /// self + other.
    pub fn add(&self, o: &DenseMatrix) -> Result<DenseMatrix> {
        crate::ensure_dims!(self.rows, o.rows, "add rows");
        crate::ensure_dims!(self.cols, o.cols, "add cols");
        Ok(DenseMatrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&o.data).map(|(a, b)| a + b).collect(),
        })
    }

    /// In-place `self += other` — what shuffle combiners use to merge
    /// partial blocks without allocating a fresh matrix per merge.
    pub fn add_assign(&mut self, o: &DenseMatrix) -> Result<()> {
        crate::ensure_dims!(self.rows, o.rows, "add rows");
        crate::ensure_dims!(self.cols, o.cols, "add cols");
        for (a, b) in self.data.iter_mut().zip(&o.data) {
            *a += b;
        }
        Ok(())
    }

    /// self - other.
    pub fn sub(&self, o: &DenseMatrix) -> Result<DenseMatrix> {
        crate::ensure_dims!(self.rows, o.rows, "sub rows");
        crate::ensure_dims!(self.cols, o.cols, "sub cols");
        Ok(DenseMatrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&o.data).map(|(a, b)| a - b).collect(),
        })
    }

    /// alpha * self.
    pub fn scale(&self, alpha: f64) -> DenseMatrix {
        DenseMatrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|x| alpha * x).collect(),
        }
    }

    /// Frobenius norm.
    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Max |entry| difference to another matrix (test helper).
    pub fn max_abs_diff(&self, o: &DenseMatrix) -> f64 {
        assert_eq!((self.rows, self.cols), (o.rows, o.cols));
        self.data
            .iter()
            .zip(&o.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Extract a sub-block (for BlockMatrix construction).
    pub fn block(&self, row0: usize, col0: usize, n_rows: usize, n_cols: usize) -> DenseMatrix {
        assert!(row0 + n_rows <= self.rows && col0 + n_cols <= self.cols);
        let mut b = DenseMatrix::zeros(n_rows, n_cols);
        for i in 0..n_rows {
            b.row_mut(i)
                .copy_from_slice(&self.row(row0 + i)[col0..col0 + n_cols]);
        }
        b
    }

    /// Vertically stack.
    pub fn vstack(blocks: &[&DenseMatrix]) -> Result<DenseMatrix> {
        if blocks.is_empty() {
            return Ok(DenseMatrix::zeros(0, 0));
        }
        let cols = blocks[0].cols;
        let mut data = vec![];
        let mut rows = 0;
        for b in blocks {
            crate::ensure_dims!(b.cols, cols, "vstack cols");
            data.extend_from_slice(&b.data);
            rows += b.rows;
        }
        Ok(DenseMatrix { rows, cols, data })
    }

    /// Pad with zero rows/cols to (r, c) — the XLA artifact-shape adapter.
    pub fn pad_to(&self, r: usize, c: usize) -> DenseMatrix {
        assert!(r >= self.rows && c >= self.cols);
        let mut out = DenseMatrix::zeros(r, c);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
        }
        out
    }

    /// Row-major f32 copy (XLA literal transfer).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }
}

impl std::fmt::Display for DenseMatrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "DenseMatrix {}x{}", self.rows, self.cols)?;
        let show_r = self.rows.min(6);
        let show_c = self.cols.min(8);
        for i in 0..show_r {
            let vals: Vec<String> =
                (0..show_c).map(|j| format!("{:>10.4}", self.get(i, j))).collect();
            let ell = if self.cols > show_c { " ..." } else { "" };
            writeln!(f, "  [{}{}]", vals.join(" "), ell)?;
        }
        if self.rows > show_r {
            writeln!(f, "  ...")?;
        }
        Ok(())
    }
}

impl crate::rdd::memory::SizeOf for DenseMatrix {
    fn heap_bytes(&self) -> usize {
        crate::rdd::memory::SizeOf::heap_bytes(&self.data)
    }
}

impl crate::rdd::memory::Spill for DenseMatrix {
    fn encode(&self, out: &mut Vec<u8>) {
        use crate::rdd::memory::Spill;
        self.rows.encode(out);
        self.cols.encode(out);
        self.data.encode(out);
    }

    fn decode(src: &mut &[u8]) -> crate::error::Result<Self> {
        use crate::rdd::memory::Spill;
        let rows = usize::decode(src)?;
        let cols = usize::decode(src)?;
        let data = Vec::<f64>::decode(src)?;
        if data.len() != rows * cols {
            return Err(crate::error::Error::msg("spill decode: DenseMatrix shape mismatch"));
        }
        Ok(DenseMatrix { rows, cols, data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_allclose, assert_close, check};

    fn small() -> DenseMatrix {
        DenseMatrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]).unwrap()
    }

    #[test]
    fn construction_and_access() {
        let m = small();
        assert_eq!((m.rows, m.cols), (3, 2));
        assert_eq!(m.get(2, 1), 6.0);
        assert_eq!(m.row(1), &[3.0, 4.0]);
        assert_eq!(m.col(0).0, vec![1.0, 3.0, 5.0]);
    }

    #[test]
    fn ragged_rows_rejected() {
        assert!(DenseMatrix::from_rows(&[vec![1.0], vec![1.0, 2.0]]).is_err());
        assert!(DenseMatrix::from_row_major(2, 2, vec![0.0; 3]).is_err());
    }

    #[test]
    fn transpose_involution() {
        let m = small();
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose().get(1, 2), 6.0);
    }

    #[test]
    fn matvec_and_tmatvec() {
        let m = small();
        let x = Vector::from(&[1.0, -1.0]);
        assert_eq!(m.matvec(&x).unwrap().0, vec![-1.0, -1.0, -1.0]);
        let y = Vector::from(&[1.0, 0.0, -1.0]);
        assert_eq!(m.tmatvec(&y).unwrap().0, vec![-4.0, -4.0]);
        assert!(m.matvec(&Vector::zeros(3)).is_err());
        assert!(m.tmatvec(&Vector::zeros(2)).is_err());
    }

    #[test]
    fn gram_matches_transpose_matmul() {
        check("gram == A^T * A", 25, |g| {
            let r = g.int(1, 12);
            let c = g.int(1, 8);
            let m = DenseMatrix::randn(r, c, g.rng());
            let gram = m.gram();
            let gram2 = m.transpose().matmul(&m).unwrap();
            assert_allclose(&gram.data, &gram2.data, 1e-10, "gram");
        });
    }

    #[test]
    fn tmatvec_consistent_with_transpose_matvec() {
        check("A^T y == (A^T) y", 25, |g| {
            let r = g.int(1, 12);
            let c = g.int(1, 9);
            let m = DenseMatrix::randn(r, c, g.rng());
            let y = Vector(g.vec_f64(0, 0).into_iter().chain((0..r).map(|_| g.normal())).collect());
            let a = m.tmatvec(&y).unwrap();
            let b = m.transpose().matvec(&y).unwrap();
            assert_allclose(&a.0, &b.0, 1e-10, "tmatvec");
        });
    }

    #[test]
    fn block_and_vstack_roundtrip() {
        let m = DenseMatrix::randn(6, 4, &mut SplitMix64::new(1));
        let top = m.block(0, 0, 3, 4);
        let bot = m.block(3, 0, 3, 4);
        let back = DenseMatrix::vstack(&[&top, &bot]).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn pad_preserves_content_and_zero_fills() {
        let m = small();
        let p = m.pad_to(5, 4);
        assert_eq!(p.get(2, 1), 6.0);
        assert_eq!(p.get(4, 3), 0.0);
        assert_close(p.frob_norm(), m.frob_norm(), 1e-15, "pad norm");
    }

    #[test]
    fn add_sub_scale() {
        let m = small();
        let s = m.add(&m).unwrap();
        assert_eq!(s, m.scale(2.0));
        let d = s.sub(&m).unwrap();
        assert_eq!(d, m);
        assert!(m.add(&DenseMatrix::zeros(1, 1)).is_err());
        let mut acc = m.clone();
        acc.add_assign(&m).unwrap();
        assert_eq!(acc, m.scale(2.0));
        assert!(acc.add_assign(&DenseMatrix::zeros(1, 1)).is_err());
    }

    #[test]
    fn display_does_not_panic() {
        let m = DenseMatrix::randn(10, 12, &mut SplitMix64::new(2));
        let s = format!("{m}");
        assert!(s.contains("10x12"));
    }
}
