//! Local (single-node) linear algebra — the paper's §2.4 "Local Vectors
//! and Matrices" plus the dense/sparse kernels that back both the driver
//! computations and the per-partition executor work when XLA artifacts are
//! not in play.
//!
//! Layout conventions: [`DenseMatrix`] is **row-major** (a `RowMatrix`
//! partition is a contiguous block of rows), [`SparseMatrix`] is CCS
//! (Compressed Column Storage), exactly the format §4.2 describes.

pub mod vector;
pub mod matrix;
pub mod sparse;
pub mod blas;
pub mod qr;
pub mod eig;
pub mod cholesky;
pub mod svd_local;

pub use matrix::DenseMatrix;
pub use sparse::{SparseMatrix, SparseVector};
pub use vector::Vector;
