//! Symmetric eigendecomposition: Householder tridiagonalization + implicit
//! QL with Wilkinson shifts (EISPACK `tred2`/`tql2` lineage — fitting,
//! given the paper's theme of reusing decades-old numerics).
//!
//! This is the *driver-local* eigensolver used by the tall-skinny SVD
//! (paper §3.1.2): A^T A is n×n with n small, so an O(n³) dense solve on
//! the driver is the right tool.

use crate::error::{Error, Result};
use crate::linalg::matrix::DenseMatrix;

/// Eigendecomposition A = V diag(λ) Vᵀ of a symmetric matrix.
/// `values` are sorted DESCENDING (the order SVD wants); `vectors`
/// columns correspond.
#[derive(Debug, Clone)]
pub struct EigResult {
    /// Eigenvalues, descending.
    pub values: Vec<f64>,
    /// Column i of `vectors` is the eigenvector for `values[i]`.
    pub vectors: DenseMatrix,
}

/// Symmetric eigendecomposition. Input must be square and (numerically)
/// symmetric; asymmetry beyond 1e-8·‖A‖ is rejected.
pub fn eig_sym(a: &DenseMatrix) -> Result<EigResult> {
    let n = a.rows;
    if a.cols != n {
        return Err(Error::dim(format!("eig_sym needs square, got {}x{}", a.rows, a.cols)));
    }
    if n == 0 {
        return Ok(EigResult { values: vec![], vectors: DenseMatrix::zeros(0, 0) });
    }
    let scale = a.frob_norm().max(1e-300);
    for i in 0..n {
        for j in 0..i {
            if (a.get(i, j) - a.get(j, i)).abs() > 1e-8 * scale {
                return Err(Error::InvalidArgument(format!(
                    "eig_sym: asymmetric at ({i},{j}): {} vs {}",
                    a.get(i, j),
                    a.get(j, i)
                )));
            }
        }
    }
    // --- tred2: tridiagonalize, accumulating transforms in z ---
    let mut z = a.clone();
    let mut d = vec![0.0f64; n]; // diagonal
    let mut e = vec![0.0f64; n]; // sub-diagonal
    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0;
        if l > 0 {
            let mut scale = 0.0;
            for k in 0..=l {
                scale += z.get(i, k).abs();
            }
            if scale == 0.0 {
                e[i] = z.get(i, l);
            } else {
                for k in 0..=l {
                    let v = z.get(i, k) / scale;
                    z.set(i, k, v);
                    h += v * v;
                }
                let mut f = z.get(i, l);
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                z.set(i, l, f - g);
                f = 0.0;
                for j in 0..=l {
                    z.set(j, i, z.get(i, j) / h);
                    let mut g = 0.0;
                    for k in 0..=j {
                        g += z.get(j, k) * z.get(i, k);
                    }
                    for k in j + 1..=l {
                        g += z.get(k, j) * z.get(i, k);
                    }
                    e[j] = g / h;
                    f += e[j] * z.get(i, j);
                }
                let hh = f / (h + h);
                for j in 0..=l {
                    let f = z.get(i, j);
                    let g = e[j] - hh * f;
                    e[j] = g;
                    for k in 0..=j {
                        let v = z.get(j, k) - (f * e[k] + g * z.get(i, k));
                        z.set(j, k, v);
                    }
                }
            }
        } else {
            e[i] = z.get(i, l);
        }
        d[i] = h;
    }
    d[0] = 0.0;
    e[0] = 0.0;
    for i in 0..n {
        if d[i] != 0.0 {
            for j in 0..i {
                let mut g = 0.0;
                for k in 0..i {
                    g += z.get(i, k) * z.get(k, j);
                }
                for k in 0..i {
                    let v = z.get(k, j) - g * z.get(k, i);
                    z.set(k, j, v);
                }
            }
        }
        d[i] = z.get(i, i);
        z.set(i, i, 1.0);
        for j in 0..i {
            z.set(j, i, 0.0);
            z.set(i, j, 0.0);
        }
    }
    // --- tql2: implicit QL on the tridiagonal, rotating z ---
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;
    for l in 0..n {
        let mut iter = 0;
        loop {
            // find small subdiagonal element
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            if iter > 50 {
                return Err(Error::NoConvergence(format!(
                    "tql2: eigenvalue {l} not converged after 50 sweeps"
                )));
            }
            // Wilkinson shift
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            let sgn = if g >= 0.0 { r.abs() } else { -r.abs() };
            g = d[m] - d[l] + e[l] / (g + sgn);
            let (mut s, mut c) = (1.0f64, 1.0f64);
            let mut p = 0.0f64;
            let mut underflow = false;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    // deflate: rotation underflowed before reaching l
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    underflow = true;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                // accumulate rotation into z
                for k in 0..n {
                    f = z.get(k, i + 1);
                    let v = z.get(k, i);
                    z.set(k, i + 1, s * v + c * f);
                    z.set(k, i, c * v - s * f);
                }
            }
            if underflow {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
    // sort descending, permuting columns of z
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| d[j].partial_cmp(&d[i]).unwrap());
    let values: Vec<f64> = order.iter().map(|&i| d[i]).collect();
    let mut vectors = DenseMatrix::zeros(n, n);
    for (new_j, &old_j) in order.iter().enumerate() {
        for i in 0..n {
            vectors.set(i, new_j, z.get(i, old_j));
        }
    }
    Ok(EigResult { values, vectors })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_allclose, check};
    use crate::util::rng::SplitMix64;

    fn random_symmetric(n: usize, rng: &mut SplitMix64) -> DenseMatrix {
        let a = DenseMatrix::randn(n, n, rng);
        a.add(&a.transpose()).unwrap().scale(0.5)
    }

    #[test]
    fn diagonal_matrix_exact() {
        let mut a = DenseMatrix::zeros(3, 3);
        a.set(0, 0, 3.0);
        a.set(1, 1, -1.0);
        a.set(2, 2, 7.0);
        let e = eig_sym(&a).unwrap();
        assert_allclose(&e.values, &[7.0, 3.0, -1.0], 1e-12, "diag eigs");
    }

    #[test]
    fn reconstruction_property() {
        check("V diag(l) V^T == A", 15, |g| {
            let n = g.int(1, 12);
            let a = random_symmetric(n, g.rng());
            let e = eig_sym(&a).unwrap();
            // rebuild
            let mut lam = DenseMatrix::zeros(n, n);
            for i in 0..n {
                lam.set(i, i, e.values[i]);
            }
            let back = e.vectors.matmul(&lam).unwrap().matmul(&e.vectors.transpose()).unwrap();
            assert!(
                back.max_abs_diff(&a) < 1e-8 * (1.0 + a.frob_norm()),
                "reconstruction err {}",
                back.max_abs_diff(&a)
            );
        });
    }

    #[test]
    fn vectors_orthonormal_property() {
        check("V^T V == I", 15, |g| {
            let n = g.int(1, 12);
            let a = random_symmetric(n, g.rng());
            let e = eig_sym(&a).unwrap();
            let vtv = e.vectors.transpose().matmul(&e.vectors).unwrap();
            assert!(vtv.max_abs_diff(&DenseMatrix::eye(n)) < 1e-9);
        });
    }

    #[test]
    fn values_sorted_descending() {
        let a = random_symmetric(10, &mut SplitMix64::new(4));
        let e = eig_sym(&a).unwrap();
        for w in e.values.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
    }

    #[test]
    fn gram_matrix_eigs_nonnegative() {
        // A^T A is PSD — eigenvalues must be >= 0 (up to roundoff); this is
        // what the tall-skinny SVD relies on.
        let mut rng = SplitMix64::new(5);
        let a = DenseMatrix::randn(30, 8, &mut rng);
        let e = eig_sym(&a.gram()).unwrap();
        for &v in &e.values {
            assert!(v > -1e-8, "negative PSD eigenvalue {v}");
        }
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigs 3, 1
        let a = DenseMatrix::from_rows(&[vec![2.0, 1.0], vec![1.0, 2.0]]).unwrap();
        let e = eig_sym(&a).unwrap();
        assert_allclose(&e.values, &[3.0, 1.0], 1e-12, "2x2 eigs");
        // eigenvector for 3 is [1,1]/sqrt(2) up to sign
        let v0 = (e.vectors.get(0, 0), e.vectors.get(1, 0));
        assert!((v0.0.abs() - std::f64::consts::FRAC_1_SQRT_2).abs() < 1e-10);
        assert!((v0.0 - v0.1).abs() < 1e-10 || (v0.0 + v0.1).abs() < 1e-10);
    }

    #[test]
    fn asymmetric_rejected() {
        let a = DenseMatrix::from_rows(&[vec![1.0, 2.0], vec![0.0, 1.0]]).unwrap();
        assert!(eig_sym(&a).is_err());
        assert!(eig_sym(&DenseMatrix::zeros(2, 3)).is_err());
    }

    #[test]
    fn empty_and_single() {
        assert!(eig_sym(&DenseMatrix::zeros(0, 0)).unwrap().values.is_empty());
        let a = DenseMatrix::from_rows(&[vec![5.0]]).unwrap();
        let e = eig_sym(&a).unwrap();
        assert_allclose(&e.values, &[5.0], 1e-15, "1x1");
    }
}
