//! Householder QR — the local factor kernel behind the distributed TSQR
//! (paper §3.4, ref \[2\]: "Direct QR factorizations for tall-and-skinny
//! matrices in MapReduce architectures").

use crate::error::{Error, Result};
use crate::linalg::matrix::DenseMatrix;

/// Result of a thin QR: `q` is m×n with orthonormal columns, `r` is n×n
/// upper triangular, A = Q R. Requires m >= n.
#[derive(Debug, Clone)]
pub struct QrResult {
    /// Orthonormal factor (m×n).
    pub q: DenseMatrix,
    /// Upper-triangular factor (n×n).
    pub r: DenseMatrix,
}

/// Thin Householder QR of an m×n matrix with m >= n.
pub fn qr_thin(a: &DenseMatrix) -> Result<QrResult> {
    let (m, n) = (a.rows, a.cols);
    if m < n {
        return Err(Error::InvalidArgument(format!(
            "qr_thin needs rows >= cols, got {m}x{n}"
        )));
    }
    // Work on a copy; accumulate Householder vectors in-place (LAPACK
    // dgeqrf layout: v's below the diagonal, R on and above).
    let mut work = a.clone();
    let mut betas = vec![0.0f64; n];
    for k in 0..n {
        // Householder vector for column k, rows k..m
        let mut alpha = 0.0;
        for i in k..m {
            let v = work.get(i, k);
            alpha += v * v;
        }
        alpha = alpha.sqrt();
        if alpha == 0.0 {
            betas[k] = 0.0;
            continue;
        }
        let akk = work.get(k, k);
        let sign = if akk >= 0.0 { 1.0 } else { -1.0 };
        let v0 = akk + sign * alpha;
        // normalize so v[k] = 1 implicitly; beta = 2 / (v^T v) with v scaled
        let mut vtv = 1.0;
        for i in k + 1..m {
            let vi = work.get(i, k) / v0;
            work.set(i, k, vi);
            vtv += vi * vi;
        }
        betas[k] = 2.0 / vtv;
        work.set(k, k, -sign * alpha); // R(k,k)
        // apply H = I - beta v v^T to remaining columns
        for j in k + 1..n {
            let mut dot = work.get(k, j); // v[k] = 1
            for i in k + 1..m {
                dot += work.get(i, k) * work.get(i, j);
            }
            let bd = betas[k] * dot;
            work.set(k, j, work.get(k, j) - bd);
            for i in k + 1..m {
                let w = work.get(i, j) - bd * work.get(i, k);
                work.set(i, j, w);
            }
        }
    }
    // extract R
    let mut r = DenseMatrix::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            r.set(i, j, work.get(i, j));
        }
    }
    // form thin Q by applying H_k ... H_1 to the first n columns of I
    let mut q = DenseMatrix::zeros(m, n);
    for j in 0..n {
        q.set(j, j, 1.0);
    }
    for k in (0..n).rev() {
        if betas[k] == 0.0 {
            continue;
        }
        for j in 0..n {
            let mut dot = q.get(k, j);
            for i in k + 1..m {
                dot += work.get(i, k) * q.get(i, j);
            }
            let bd = betas[k] * dot;
            q.set(k, j, q.get(k, j) - bd);
            for i in k + 1..m {
                let w = q.get(i, j) - bd * work.get(i, k);
                q.set(i, j, w);
            }
        }
    }
    Ok(QrResult { q, r })
}

/// Force R to have a non-negative diagonal (flips matching Q columns) —
/// makes the factorization unique, which TSQR's tests rely on.
pub fn canonicalize(qr: &mut QrResult) {
    let n = qr.r.cols;
    for j in 0..n {
        if qr.r.get(j, j) < 0.0 {
            for jj in j..n {
                let v = qr.r.get(j, jj);
                qr.r.set(j, jj, -v);
            }
            for i in 0..qr.q.rows {
                let v = qr.q.get(i, j);
                qr.q.set(i, j, -v);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::SplitMix64;

    fn assert_orthonormal(q: &DenseMatrix, tol: f64) {
        let qtq = q.transpose().matmul(q).unwrap();
        let eye = DenseMatrix::eye(q.cols);
        assert!(
            qtq.max_abs_diff(&eye) < tol,
            "Q^T Q != I (err {})",
            qtq.max_abs_diff(&eye)
        );
    }

    #[test]
    fn reconstructs_a_property() {
        check("QR reconstructs A", 20, |g| {
            let n = g.int(1, 10);
            let m = n + g.int(0, 20);
            let a = DenseMatrix::randn(m, n, g.rng());
            let qr = qr_thin(&a).unwrap();
            let back = qr.q.matmul(&qr.r).unwrap();
            assert!(back.max_abs_diff(&a) < 1e-9, "A != QR");
            assert_orthonormal(&qr.q, 1e-9);
        });
    }

    #[test]
    fn r_is_upper_triangular() {
        let a = DenseMatrix::randn(8, 5, &mut SplitMix64::new(1));
        let qr = qr_thin(&a).unwrap();
        for i in 0..5 {
            for j in 0..i {
                assert_eq!(qr.r.get(i, j), 0.0);
            }
        }
    }

    #[test]
    fn wide_matrix_rejected() {
        let a = DenseMatrix::zeros(2, 5);
        assert!(qr_thin(&a).is_err());
    }

    #[test]
    fn rank_deficient_survives() {
        // two identical columns
        let mut a = DenseMatrix::randn(6, 3, &mut SplitMix64::new(2));
        for i in 0..6 {
            let v = a.get(i, 0);
            a.set(i, 1, v);
        }
        let qr = qr_thin(&a).unwrap();
        let back = qr.q.matmul(&qr.r).unwrap();
        assert!(back.max_abs_diff(&a) < 1e-9);
    }

    #[test]
    fn canonical_r_diag_nonnegative() {
        let a = DenseMatrix::randn(7, 4, &mut SplitMix64::new(3));
        let mut qr = qr_thin(&a).unwrap();
        canonicalize(&mut qr);
        for j in 0..4 {
            assert!(qr.r.get(j, j) >= 0.0);
        }
        let back = qr.q.matmul(&qr.r).unwrap();
        assert!(back.max_abs_diff(&a) < 1e-9);
    }

    #[test]
    fn square_identity() {
        // Householder sign convention gives Q = R = -I; canonicalize
        // (non-negative R diagonal) recovers exactly I.
        let i5 = DenseMatrix::eye(5);
        let mut qr = qr_thin(&i5).unwrap();
        canonicalize(&mut qr);
        assert!(qr.q.max_abs_diff(&i5) < 1e-12);
        assert!(qr.r.max_abs_diff(&i5) < 1e-12);
    }
}
