//! BLAS level 2: matrix–vector kernels.

use crate::linalg::matrix::DenseMatrix;
use crate::linalg::vector::blas_dot;

/// gemv: y = alpha A x + beta y (row-major A: one dot per row).
pub fn gemv(alpha: f64, a: &DenseMatrix, x: &[f64], beta: f64, y: &mut [f64]) {
    debug_assert_eq!(a.cols, x.len());
    debug_assert_eq!(a.rows, y.len());
    for (i, yi) in y.iter_mut().enumerate() {
        *yi = alpha * blas_dot(a.row(i), x) + beta * *yi;
    }
}

/// gemv_t: y = alpha Aᵀ x + beta y (single pass over A's rows; saxpy per
/// row — avoids materializing Aᵀ).
pub fn gemv_t(alpha: f64, a: &DenseMatrix, x: &[f64], beta: f64, y: &mut [f64]) {
    debug_assert_eq!(a.rows, x.len());
    debug_assert_eq!(a.cols, y.len());
    if beta != 1.0 {
        for yi in y.iter_mut() {
            *yi *= beta;
        }
    }
    for (i, &xi) in x.iter().enumerate() {
        let axi = alpha * xi;
        if axi == 0.0 {
            continue;
        }
        for (yj, &aij) in y.iter_mut().zip(a.row(i)) {
            *yj += axi * aij;
        }
    }
}

/// ger: A += alpha x yᵀ (rank-1 update).
pub fn ger(alpha: f64, x: &[f64], y: &[f64], a: &mut DenseMatrix) {
    debug_assert_eq!(a.rows, x.len());
    debug_assert_eq!(a.cols, y.len());
    for (i, &xi) in x.iter().enumerate() {
        let axi = alpha * xi;
        for (aij, &yj) in a.row_mut(i).iter_mut().zip(y) {
            *aij += axi * yj;
        }
    }
}

/// symv for a symmetric A (stored full): y = A x exploiting nothing —
/// kept for API parity; symmetric storage isn't worth it at our sizes.
pub fn symv(a: &DenseMatrix, x: &[f64], y: &mut [f64]) {
    gemv(1.0, a, x, 0.0, y);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_allclose, check};
    use crate::util::rng::SplitMix64;

    #[test]
    fn gemv_alpha_beta() {
        let a = DenseMatrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        let mut y = vec![100.0, 200.0];
        gemv(2.0, &a, &[1.0, 1.0], 0.5, &mut y);
        assert_eq!(y, vec![2.0 * 3.0 + 50.0, 2.0 * 7.0 + 100.0]);
    }

    #[test]
    fn gemv_t_matches_transpose_property() {
        check("gemv_t == gemv on transpose", 30, |g| {
            let r = g.int(1, 15);
            let c = g.int(1, 12);
            let a = DenseMatrix::randn(r, c, g.rng());
            let x: Vec<f64> = (0..r).map(|_| g.normal()).collect();
            let mut y1 = vec![0.3; c];
            let mut y2 = vec![0.3; c];
            gemv_t(1.7, &a, &x, 0.4, &mut y1);
            gemv(1.7, &a.transpose(), &x, 0.4, &mut y2);
            assert_allclose(&y1, &y2, 1e-10, "gemv_t");
        });
    }

    #[test]
    fn ger_rank1() {
        let mut a = DenseMatrix::zeros(2, 3);
        ger(2.0, &[1.0, -1.0], &[1.0, 2.0, 3.0], &mut a);
        assert_eq!(a.row(0), &[2.0, 4.0, 6.0]);
        assert_eq!(a.row(1), &[-2.0, -4.0, -6.0]);
    }

    #[test]
    fn symv_delegates() {
        let a = DenseMatrix::randn(4, 4, &mut SplitMix64::new(3));
        let sym = a.add(&a.transpose()).unwrap();
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let mut y = vec![0.0; 4];
        symv(&sym, &x, &mut y);
        let want = sym.matvec(&crate::linalg::vector::Vector(x)).unwrap();
        assert_allclose(&y, &want.0, 1e-12, "symv");
    }
}
