//! BLAS-style dense kernels — the local compute the paper pushes to
//! hardware (§4). Three GEMM backends mirror the paper's Fig. 2 ladder:
//!
//! * [`level3::gemm_naive`] — the `f2jblas` analog: straight triple loop.
//! * [`level3::gemm_blocked`] — cache-tiled single-thread (what a good
//!   portable BLAS does).
//! * [`level3::gemm_parallel`] — blocked + threads (the OpenBLAS analog).
//!
//! The fourth and fifth backends of our Fig.-2 reproduction — XLA HLO and
//! the Pallas-lowered HLO — live in `runtime::ops` (they need PJRT).

pub mod level1;
pub mod level2;
pub mod level3;

/// Which GEMM backend to use — selectable per call and benchmarked
/// head-to-head in `bench_gemm` (Fig. 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GemmBackend {
    /// Triple loop, no tiling (f2jblas analog).
    Naive,
    /// Cache-tiled, single thread.
    Blocked,
    /// Cache-tiled, multi-threaded (OpenBLAS analog).
    Parallel,
}

impl std::str::FromStr for GemmBackend {
    type Err = crate::error::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "naive" => Ok(GemmBackend::Naive),
            "blocked" => Ok(GemmBackend::Blocked),
            "parallel" => Ok(GemmBackend::Parallel),
            other => Err(crate::error::Error::InvalidArgument(format!(
                "unknown gemm backend {other:?} (naive|blocked|parallel)"
            ))),
        }
    }
}
