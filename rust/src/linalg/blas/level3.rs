//! BLAS level 3: GEMM backends for the Fig. 2 reproduction.
//!
//! The performance ladder (naive → blocked → parallel) demonstrates the
//! paper's §4 point on hardware-aware kernels; absolute numbers are in
//! EXPERIMENTS.md (§Fig2). Tile size is tuned in the §Perf pass.

use crate::linalg::matrix::DenseMatrix;
use crate::util::pool;

use super::GemmBackend;

/// Cache tile edge: 3 tiles of 128×128 f64 = 384 KiB, L2-resident on the
/// testbed. Swept {64, 128, 256} in the perf pass (EXPERIMENTS.md §Perf):
/// 64 and 128 within noise at 128³, 128 ~8% ahead at 256³, 256 regressed.
pub const TILE: usize = 128;

/// Dispatch by backend.
pub fn gemm(backend: GemmBackend, a: &DenseMatrix, b: &DenseMatrix) -> DenseMatrix {
    match backend {
        GemmBackend::Naive => gemm_naive(a, b),
        GemmBackend::Blocked => gemm_blocked(a, b),
        GemmBackend::Parallel => gemm_parallel(a, b),
    }
}

/// Triple loop in the natural (i, k, j) order. This is the `f2jblas`
/// analog: correct, portable, no tiling. (i,k,j) rather than (i,j,k) so
/// the inner loop is still a contiguous saxpy — honest baseline, not a
/// strawman.
pub fn gemm_naive(a: &DenseMatrix, b: &DenseMatrix) -> DenseMatrix {
    assert_eq!(a.cols, b.rows, "gemm inner dims");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = DenseMatrix::zeros(m, n);
    for i in 0..m {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for (p, &aip) in arow.iter().enumerate().take(k) {
            if aip == 0.0 {
                continue;
            }
            let brow = b.row(p);
            for j in 0..n {
                crow[j] += aip * brow[j];
            }
        }
    }
    c
}

/// Cache-tiled GEMM: (ii, pp, jj) tile loops, micro-kernel is the same
/// saxpy row update but confined to a TILE×TILE working set so B's panel
/// stays in L1/L2 across the ii loop.
pub fn gemm_blocked(a: &DenseMatrix, b: &DenseMatrix) -> DenseMatrix {
    assert_eq!(a.cols, b.rows, "gemm inner dims");
    let (m, n) = (a.rows, b.cols);
    let mut c = DenseMatrix::zeros(m, n);
    gemm_blocked_into(a, b, &mut c, 0, m);
    c
}

/// Accumulating GEMM `c += a·b` via the blocked tiled driver — the
/// kernel BlockMatrix's simulate-multiply reduce uses to fold partial
/// block products **in place** (no fresh matrix per partial).
pub fn gemm_acc(a: &DenseMatrix, b: &DenseMatrix, c: &mut DenseMatrix) {
    assert_eq!(a.cols, b.rows, "gemm inner dims");
    assert_eq!((c.rows, c.cols), (a.rows, b.cols), "gemm_acc output dims");
    gemm_blocked_into(a, b, c, 0, a.rows);
}

/// Tiled update of C rows [row0, row1) — shared by the serial and
/// parallel drivers (the parallel backend splits the row range).
fn gemm_blocked_into(a: &DenseMatrix, b: &DenseMatrix, c: &mut DenseMatrix, row0: usize, row1: usize) {
    let (k, n) = (a.cols, b.cols);
    let nc = c.cols;
    let bn = b.cols;
    for pp in (0..k).step_by(TILE) {
        let p_end = (pp + TILE).min(k);
        for jj in (0..n).step_by(TILE) {
            let j_end = (jj + TILE).min(n);
            let jw = j_end - jj;
            for i in row0..row1 {
                let arow = a.row(i);
                let crow = &mut c.data[i * nc + jj..i * nc + j_end];
                // k-unrolled micro-kernel: 4 rows of B per pass over the
                // C tile ⇒ 8 flops per C load+store instead of 2 (the
                // §Perf register-blocking change; see EXPERIMENTS.md).
                let mut p = pp;
                while p + 4 <= p_end {
                    let (a0, a1, a2, a3) = (arow[p], arow[p + 1], arow[p + 2], arow[p + 3]);
                    let b0 = &b.data[p * bn + jj..p * bn + j_end];
                    let b1 = &b.data[(p + 1) * bn + jj..(p + 1) * bn + j_end];
                    let b2 = &b.data[(p + 2) * bn + jj..(p + 2) * bn + j_end];
                    let b3 = &b.data[(p + 3) * bn + jj..(p + 3) * bn + j_end];
                    for j in 0..jw {
                        crow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
                    }
                    p += 4;
                }
                while p < p_end {
                    let aip = arow[p];
                    if aip != 0.0 {
                        let brow = &b.row(p)[jj..j_end];
                        for (cv, &bv) in crow.iter_mut().zip(brow) {
                            *cv += aip * bv;
                        }
                    }
                    p += 1;
                }
            }
        }
    }
}

/// Blocked + multi-threaded over row bands (the OpenBLAS analog).
/// Band tasks run on the cluster's work-stealing worker pool when one
/// is registered (`util::pool::shared_pool`), so a driver-side GEMM
/// shares cores with cluster tasks instead of spawning ad-hoc threads;
/// with no pool (pure-local use) it falls back to scoped threads. A
/// GEMM invoked *from* a pool worker stays serial — the task is already
/// one of N parallel tasks, and nesting would oversubscribe the cores.
pub fn gemm_parallel(a: &DenseMatrix, b: &DenseMatrix) -> DenseMatrix {
    assert_eq!(a.cols, b.rows, "gemm inner dims");
    let (m, n) = (a.rows, b.cols);
    let threads = pool::local_threads().min(m.max(1));
    if threads <= 1 || m * n < 64 * 64 || pool::in_pool_worker() {
        return gemm_blocked(a, b);
    }
    let mut c = DenseMatrix::zeros(m, n);
    if let Some(p) = pool::shared_pool() {
        if gemm_parallel_pooled(&*p, a, b, &mut c, threads) {
            return c;
        }
        // partial batch (pool shutting down): reset the accumulator
        // before recomputing — run_batch has quiesced every task
        c.data.fill(0.0);
    }
    gemm_parallel_scoped(a, b, &mut c, threads);
    c
}

/// Row-band GEMM on the shared worker pool. Returns false (after all
/// submitted tasks quiesced) if the pool could not run the whole batch.
// lint:allow(SL001) deliberate per-band local accumulators + boxed task
// submission; the zero-alloc hot paths are gemm_acc / gemm_blocked_into
fn gemm_parallel_pooled(
    p: &dyn pool::TaskPool,
    a: &DenseMatrix,
    b: &DenseMatrix,
    c: &mut DenseMatrix,
    threads: usize,
) -> bool {
    let (m, n) = (a.rows, b.cols);
    /// Raw handles a band task dereferences; Send because the bands are
    /// disjoint and `run_batch` outlives every task.
    struct BandTask {
        a: *const DenseMatrix,
        b: *const DenseMatrix,
        c: *mut f64,
        row0: usize,
        band: usize,
        n: usize,
    }
    unsafe impl Send for BandTask {}
    let rows_per = m.div_ceil(threads);
    let mut tasks: Vec<Box<dyn FnOnce() + Send>> = Vec::new();
    let c_ptr = c.data.as_mut_ptr();
    let mut row0 = 0usize;
    while row0 < m {
        let band = rows_per.min(m - row0);
        let t = BandTask {
            a: a as *const DenseMatrix,
            b: b as *const DenseMatrix,
            c: c_ptr,
            row0,
            band,
            n,
        };
        tasks.push(Box::new(move || {
            // SAFETY: `run_batch` does not return until this task has
            // finished or been dropped unrun, so `a`, `b`, and `c`
            // outlive the dereference; each task writes a disjoint
            // row band of C, so the mutable slices never alias.
            let (a, b) = unsafe { (&*t.a, &*t.b) };
            let chunk =
                unsafe { std::slice::from_raw_parts_mut(t.c.add(t.row0 * t.n), t.band * t.n) };
            let a_band = a_rows_view(a, t.row0, t.band);
            let mut local = DenseMatrix { rows: t.band, cols: t.n, data: vec![0.0; t.band * t.n] };
            gemm_blocked_into(&a_band, b, &mut local, 0, t.band);
            chunk.copy_from_slice(&local.data);
        }));
        row0 += band;
    }
    p.run_batch(tasks)
}

/// Scoped-thread fallback (no shared pool registered).
// lint:allow(SL001) per-band local accumulators, folded into `c` once per band
fn gemm_parallel_scoped(a: &DenseMatrix, b: &DenseMatrix, c: &mut DenseMatrix, threads: usize) {
    let (m, n) = (a.rows, b.cols);
    // split C's rows into `threads` contiguous bands
    let rows_per = m.div_ceil(threads);
    std::thread::scope(|s| {
        let mut rest: &mut [f64] = &mut c.data;
        let mut row0 = 0;
        while row0 < m {
            let band = rows_per.min(m - row0);
            let (chunk, tail) = rest.split_at_mut(band * n);
            rest = tail;
            let r0 = row0;
            s.spawn(move || {
                // compute the band into a local matrix, then copy into C's
                // disjoint slice (the tiled driver wants a DenseMatrix)
                let mut local = DenseMatrix { rows: band, cols: n, data: vec![0.0; band * n] };
                let a_band = a_rows_view(a, r0, band);
                gemm_blocked_into(&a_band, b, &mut local, 0, band);
                chunk.copy_from_slice(&local.data);
            });
            row0 += band;
        }
    });
}

/// Copy of rows [row0, row0+band) of A (bands are reused across all B
/// tiles, so one copy per thread is cheap relative to the multiply).
fn a_rows_view(a: &DenseMatrix, row0: usize, band: usize) -> DenseMatrix {
    DenseMatrix {
        rows: band,
        cols: a.cols,
        data: a.data[row0 * a.cols..(row0 + band) * a.cols].to_vec(),
    }
}

/// FLOP count of a GEMM (2·m·k·n) — used by the bench harness to report
/// GFLOP/s like the paper's Fig. 2 y-axis.
pub fn gemm_flops(m: usize, k: usize, n: usize) -> f64 {
    2.0 * m as f64 * k as f64 * n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_allclose, check};
    use crate::util::rng::SplitMix64;

    #[test]
    fn all_backends_agree_property() {
        check("naive == blocked == parallel", 15, |g| {
            let m = g.int(1, 40);
            let k = g.int(1, 40);
            let n = g.int(1, 40);
            let a = DenseMatrix::randn(m, k, g.rng());
            let b = DenseMatrix::randn(k, n, g.rng());
            let c1 = gemm_naive(&a, &b);
            let c2 = gemm_blocked(&a, &b);
            let c3 = gemm_parallel(&a, &b);
            assert_allclose(&c1.data, &c2.data, 1e-10, "naive vs blocked");
            assert_allclose(&c1.data, &c3.data, 1e-10, "naive vs parallel");
        });
    }

    #[test]
    fn identity_multiplication() {
        let a = DenseMatrix::randn(7, 7, &mut SplitMix64::new(1));
        let i = DenseMatrix::eye(7);
        for backend in [GemmBackend::Naive, GemmBackend::Blocked, GemmBackend::Parallel] {
            let c = gemm(backend, &a, &i);
            assert!(c.max_abs_diff(&a) < 1e-12, "{backend:?}");
        }
    }

    #[test]
    fn non_square_tile_boundaries() {
        // shapes straddling TILE boundaries exercise edge tiles
        let mut rng = SplitMix64::new(2);
        for (m, k, n) in [(TILE - 1, TILE + 1, 2 * TILE), (1, 200, 3), (130, 65, 129)] {
            let a = DenseMatrix::randn(m, k, &mut rng);
            let b = DenseMatrix::randn(k, n, &mut rng);
            let c1 = gemm_naive(&a, &b);
            let c2 = gemm_blocked(&a, &b);
            let c3 = gemm_parallel(&a, &b);
            assert!(c1.max_abs_diff(&c2) < 1e-10);
            assert!(c1.max_abs_diff(&c3) < 1e-10);
        }
    }

    #[test]
    fn parallel_handles_more_threads_than_rows() {
        let mut rng = SplitMix64::new(3);
        let a = DenseMatrix::randn(2, 100, &mut rng);
        let b = DenseMatrix::randn(100, 100, &mut rng);
        let c = gemm_parallel(&a, &b);
        assert!(c.max_abs_diff(&gemm_naive(&a, &b)) < 1e-10);
    }

    #[test]
    fn flops_formula() {
        assert_eq!(gemm_flops(2, 3, 4), 48.0);
    }

    #[test]
    fn gemm_acc_accumulates_in_place() {
        let mut rng = SplitMix64::new(9);
        let a1 = DenseMatrix::randn(13, 7, &mut rng);
        let a2 = DenseMatrix::randn(13, 7, &mut rng);
        let b = DenseMatrix::randn(7, 5, &mut rng);
        let mut c = DenseMatrix::zeros(13, 5);
        gemm_acc(&a1, &b, &mut c);
        gemm_acc(&a2, &b, &mut c);
        let want = gemm_naive(&a1, &b).add(&gemm_naive(&a2, &b)).unwrap();
        assert!(c.max_abs_diff(&want) < 1e-10);
    }

    #[test]
    #[should_panic(expected = "gemm_acc output dims")]
    fn gemm_acc_rejects_bad_output_shape() {
        let a = DenseMatrix::zeros(2, 3);
        let b = DenseMatrix::zeros(3, 4);
        let mut c = DenseMatrix::zeros(2, 3);
        gemm_acc(&a, &b, &mut c);
    }

    #[test]
    fn parallel_gemm_routes_through_cluster_pool() {
        // a live Context registers its worker pool; the driver-side GEMM
        // must stay correct through the pooled band path (and through
        // the serial guard when invoked from a worker)
        let ctx = crate::Context::local("gemm_pool_test", 2);
        let mut rng = SplitMix64::new(11);
        let a = DenseMatrix::randn(150, 90, &mut rng);
        let b = DenseMatrix::randn(90, 110, &mut rng);
        let want = gemm_naive(&a, &b);
        assert!(gemm_parallel(&a, &b).max_abs_diff(&want) < 1e-10);
        // from inside a cluster task: the in-worker guard goes serial
        let pair = std::sync::Arc::new((a, b));
        let p2 = std::sync::Arc::clone(&pair);
        let from_task = ctx
            .parallelize(vec![0usize], 1)
            .map(move |_| gemm_parallel(&p2.0, &p2.1).data.clone())
            .collect()
            .unwrap();
        crate::util::prop::assert_allclose(&from_task[0], &want.data, 1e-10, "in-task gemm");
    }

    #[test]
    #[should_panic(expected = "inner dims")]
    fn dim_mismatch_panics() {
        let a = DenseMatrix::zeros(2, 3);
        let b = DenseMatrix::zeros(4, 2);
        gemm_naive(&a, &b);
    }
}
