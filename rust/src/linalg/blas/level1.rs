//! BLAS level 1: vector–vector kernels (driver-side hot loops).

/// dot: xᵀy. Unrolled 4-way (see `vector::blas_dot` for rationale).
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    crate::linalg::vector::blas_dot(x, y)
}

/// axpy: y += alpha x.
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// scal: x *= alpha.
pub fn scal(alpha: f64, x: &mut [f64]) {
    for xi in x {
        *xi *= alpha;
    }
}

/// nrm2: ‖x‖₂ with overflow-safe scaling (LAPACK dnrm2-style).
pub fn nrm2(x: &[f64]) -> f64 {
    let mut scale = 0.0f64;
    let mut ssq = 1.0f64;
    for &xi in x {
        if xi != 0.0 {
            let a = xi.abs();
            if scale < a {
                ssq = 1.0 + ssq * (scale / a) * (scale / a);
                scale = a;
            } else {
                ssq += (a / scale) * (a / scale);
            }
        }
    }
    scale * ssq.sqrt()
}

/// asum: Σ|xᵢ|.
pub fn asum(x: &[f64]) -> f64 {
    x.iter().map(|v| v.abs()).sum()
}

/// iamax: index of max |xᵢ| (0 for empty).
pub fn iamax(x: &[f64]) -> usize {
    x.iter()
        .enumerate()
        .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_close, check};

    #[test]
    fn axpy_scal_basic() {
        let mut y = vec![1.0, 2.0];
        axpy(2.0, &[10.0, 20.0], &mut y);
        assert_eq!(y, vec![21.0, 42.0]);
        scal(0.5, &mut y);
        assert_eq!(y, vec![10.5, 21.0]);
    }

    #[test]
    fn nrm2_overflow_safe() {
        let big = 1e200;
        let v = vec![big, big];
        assert_close(nrm2(&v), big * 2f64.sqrt(), 1e-12, "no overflow");
        assert_eq!(nrm2(&[]), 0.0);
        assert_eq!(nrm2(&[0.0, 0.0]), 0.0);
    }

    #[test]
    fn nrm2_matches_naive_property() {
        check("nrm2 == sqrt(sum sq)", 30, |g| {
            let xs = g.vec_f64(1, 100);
            let naive = xs.iter().map(|x| x * x).sum::<f64>().sqrt();
            assert_close(nrm2(&xs), naive, 1e-12, "nrm2");
        });
    }

    #[test]
    fn iamax_picks_largest_magnitude() {
        assert_eq!(iamax(&[1.0, -5.0, 3.0]), 1);
        assert_eq!(iamax(&[]), 0);
    }

    #[test]
    fn asum_basic() {
        assert_eq!(asum(&[1.0, -2.0, 3.0]), 6.0);
    }
}
