//! Local (single-node) SVD via the Gram-eigen route — the same math the
//! paper's tall-skinny path uses (§3.1.2), applied locally. Serves as the
//! reference oracle for the distributed SVD tests and as the driver-side
//! finish step.

use crate::error::{Error, Result};
use crate::linalg::eig::eig_sym;
use crate::linalg::matrix::DenseMatrix;

/// Thin SVD: A = U diag(s) Vᵀ with k = min(requested, rank-ish) columns.
#[derive(Debug, Clone)]
pub struct SvdResult {
    /// Left singular vectors (m×k).
    pub u: DenseMatrix,
    /// Singular values, descending (k).
    pub s: Vec<f64>,
    /// Right singular vectors (n×k).
    pub v: DenseMatrix,
}

impl SvdResult {
    /// Reconstruct U diag(s) Vᵀ (test helper).
    pub fn reconstruct(&self) -> DenseMatrix {
        let k = self.s.len();
        let mut us = self.u.clone();
        for j in 0..k {
            for i in 0..us.rows {
                let v = us.get(i, j) * self.s[j];
                us.set(i, j, v);
            }
        }
        us.matmul(&self.v.transpose()).expect("shapes agree")
    }
}

/// Rank-k SVD of a dense matrix via eig(AᵀA) (requires m >= n to be
/// efficient; callers should transpose wide matrices — the paper makes
/// the same note in §3.1).
///
/// `rcond`: singular values below `rcond * s_max` are dropped (their
/// singular vectors are numerical noise — U columns would blow up in the
/// `A V Σ⁻¹` recovery).
pub fn svd_via_gram(a: &DenseMatrix, k: usize, rcond: f64) -> Result<SvdResult> {
    if k == 0 {
        return Err(Error::InvalidArgument("svd: k must be >= 1".into()));
    }
    let g = a.gram();
    svd_from_gram(a, &g, k, rcond)
}

/// Same, but with a precomputed Gram matrix (the distributed path computes
/// G on the cluster and finishes here on the driver).
pub fn svd_from_gram(a: &DenseMatrix, g: &DenseMatrix, k: usize, rcond: f64) -> Result<SvdResult> {
    let n = a.cols;
    crate::ensure_dims!(g.rows, n, "gram rows");
    crate::ensure_dims!(g.cols, n, "gram cols");
    let eig = eig_sym(g)?;
    let k = k.min(n);
    // eigenvalues of A^T A = squared singular values
    let s_max = eig.values.first().copied().unwrap_or(0.0).max(0.0).sqrt();
    let mut s = vec![];
    let mut keep = vec![];
    for i in 0..k {
        let sv = eig.values[i].max(0.0).sqrt();
        if sv > rcond * s_max && sv > 0.0 {
            s.push(sv);
            keep.push(i);
        }
    }
    if s.is_empty() {
        return Err(Error::InvalidArgument(
            "svd: matrix is (numerically) zero — no singular triplets above rcond".into(),
        ));
    }
    let kk = s.len();
    let mut v = DenseMatrix::zeros(n, kk);
    for (jj, &i) in keep.iter().enumerate() {
        for r in 0..n {
            v.set(r, jj, eig.vectors.get(r, i));
        }
    }
    // U = A V Σ^{-1}
    let mut vs = v.clone();
    for j in 0..kk {
        let inv = 1.0 / s[j];
        for i in 0..n {
            let val = vs.get(i, j) * inv;
            vs.set(i, j, val);
        }
    }
    let u = a.matmul(&vs)?;
    Ok(SvdResult { u, s, v })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_allclose, check};
    use crate::util::rng::SplitMix64;

    #[test]
    fn full_rank_reconstruction_property() {
        check("U s V^T == A (full k)", 15, |g| {
            let n = g.int(1, 8);
            let m = n + g.int(0, 15);
            let a = DenseMatrix::randn(m, n, g.rng());
            let svd = svd_via_gram(&a, n, 1e-12).unwrap();
            let back = svd.reconstruct();
            assert!(
                back.max_abs_diff(&a) < 1e-7 * (1.0 + a.frob_norm()),
                "err {}",
                back.max_abs_diff(&a)
            );
        });
    }

    #[test]
    fn singular_values_match_known() {
        // A = diag(3, 2) stacked with zeros: singular values 3, 2
        let a = DenseMatrix::from_rows(&[
            vec![3.0, 0.0],
            vec![0.0, 2.0],
            vec![0.0, 0.0],
        ])
        .unwrap();
        let svd = svd_via_gram(&a, 2, 1e-12).unwrap();
        assert_allclose(&svd.s, &[3.0, 2.0], 1e-10, "sv");
    }

    #[test]
    fn orthonormal_factors() {
        let mut rng = SplitMix64::new(1);
        let a = DenseMatrix::randn(40, 6, &mut rng);
        let svd = svd_via_gram(&a, 6, 1e-12).unwrap();
        let utu = svd.u.transpose().matmul(&svd.u).unwrap();
        let vtv = svd.v.transpose().matmul(&svd.v).unwrap();
        assert!(utu.max_abs_diff(&DenseMatrix::eye(6)) < 1e-8, "U orth");
        assert!(vtv.max_abs_diff(&DenseMatrix::eye(6)) < 1e-8, "V orth");
    }

    #[test]
    fn rank_deficient_truncates() {
        // rank-2 matrix from outer products
        let mut rng = SplitMix64::new(2);
        let b = DenseMatrix::randn(20, 2, &mut rng);
        let c = DenseMatrix::randn(2, 5, &mut rng);
        let a = b.matmul(&c).unwrap();
        let svd = svd_via_gram(&a, 5, 1e-9).unwrap();
        assert_eq!(svd.s.len(), 2, "rank-2 should keep 2 triplets, got {:?}", svd.s);
        let back = svd.reconstruct();
        assert!(back.max_abs_diff(&a) < 1e-7 * (1.0 + a.frob_norm()));
    }

    #[test]
    fn top_k_truncation_is_best_approx() {
        let mut rng = SplitMix64::new(3);
        let a = DenseMatrix::randn(30, 8, &mut rng);
        let svd_full = svd_via_gram(&a, 8, 1e-14).unwrap();
        let svd_k = svd_via_gram(&a, 3, 1e-14).unwrap();
        assert_eq!(svd_k.s.len(), 3);
        assert_allclose(&svd_k.s, &svd_full.s[..3], 1e-9, "top-3 match");
        // Eckart–Young: residual^2 == sum of dropped squared singular values
        let resid = a.sub(&svd_k.reconstruct()).unwrap().frob_norm();
        let dropped: f64 = svd_full.s[3..].iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!((resid - dropped).abs() < 1e-6 * (1.0 + dropped));
    }

    #[test]
    fn zero_matrix_rejected() {
        let a = DenseMatrix::zeros(5, 3);
        assert!(svd_via_gram(&a, 2, 1e-12).is_err());
        assert!(svd_via_gram(&DenseMatrix::eye(3), 0, 1e-12).is_err());
    }
}
