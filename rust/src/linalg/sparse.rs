//! Sparse local types: `SparseVector` (parallel index/value arrays, the
//! paper's §2.4 format) and `SparseMatrix` in CCS (Compressed Column
//! Storage, §4.2), with the specialized kernels the paper benchmarks:
//! Sparse×DenseVector and Sparse×DenseMatrix, optionally transposed.
//!
//! The distributed sparse engine builds on the [`CsrMatrix`] /
//! [`CscMatrix`] pair added here: allocation-free `spmv_into` /
//! `rspmv_into` accumulator kernels (callers lease the accumulator from
//! the cluster `VecPool`) plus the `spmm_acc` family (`C += A·B` for
//! sparse×dense, dense×sparse, and sparse×sparse with a dense
//! accumulator) that `BlockMatrix`'s simulate-multiply dispatches per
//! block pair.

use crate::error::{Error, Result};
use crate::linalg::matrix::DenseMatrix;
use crate::linalg::vector::Vector;
use crate::util::rng::SplitMix64;

/// Sparse vector: sorted `indices` with matching `values` (paper §2.4:
/// "(3, [0, 2], [1.0, 3.0])").
#[derive(Debug, Clone, PartialEq)]
pub struct SparseVector {
    /// Logical length.
    pub size: usize,
    /// Sorted nonzero indices.
    pub indices: Vec<u32>,
    /// Values parallel to `indices`.
    pub values: Vec<f64>,
}

impl SparseVector {
    /// Build, validating sortedness and bounds.
    pub fn new(size: usize, indices: Vec<u32>, values: Vec<f64>) -> Result<SparseVector> {
        if indices.len() != values.len() {
            return Err(Error::dim(format!(
                "sparse vector: {} indices vs {} values",
                indices.len(),
                values.len()
            )));
        }
        for w in indices.windows(2) {
            if w[0] >= w[1] {
                return Err(Error::InvalidArgument("indices must be strictly increasing".into()));
            }
        }
        if let Some(&last) = indices.last() {
            if last as usize >= size {
                return Err(Error::InvalidArgument(format!("index {last} >= size {size}")));
            }
        }
        Ok(SparseVector { size, indices, values })
    }

    /// From a dense slice, dropping zeros.
    pub fn from_dense(xs: &[f64]) -> SparseVector {
        let mut indices = vec![];
        let mut values = vec![];
        for (i, &x) in xs.iter().enumerate() {
            if x != 0.0 {
                indices.push(i as u32);
                values.push(x);
            }
        }
        SparseVector { size: xs.len(), indices, values }
    }

    /// Densify.
    pub fn to_dense(&self) -> Vector {
        let mut v = vec![0.0; self.size];
        for (&i, &x) in self.indices.iter().zip(&self.values) {
            v[i as usize] = x;
        }
        Vector(v)
    }

    /// Number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Dot with a dense vector.
    pub fn dot_dense(&self, d: &Vector) -> f64 {
        debug_assert_eq!(self.size, d.len());
        self.indices
            .iter()
            .zip(&self.values)
            .map(|(&i, &x)| x * d[i as usize])
            .sum()
    }

    /// Squared 2-norm.
    pub fn norm2_sq(&self) -> f64 {
        self.values.iter().map(|x| x * x).sum()
    }
}

/// CCS sparse matrix (MLlib `SparseMatrix`): `col_ptrs` of length
/// `cols + 1`; `row_indices[col_ptrs[j]..col_ptrs[j+1]]` are the (sorted)
/// row indices of column j.
#[derive(Debug, Clone, PartialEq)]
pub struct SparseMatrix {
    /// Rows.
    pub rows: usize,
    /// Cols.
    pub cols: usize,
    /// Column pointers, len cols+1.
    pub col_ptrs: Vec<usize>,
    /// Row index per stored value.
    pub row_indices: Vec<u32>,
    /// Stored values.
    pub values: Vec<f64>,
}

impl SparseMatrix {
    /// From COO triplets (unsorted ok; duplicates summed).
    pub fn from_coo(rows: usize, cols: usize, mut entries: Vec<(usize, usize, f64)>) -> Result<SparseMatrix> {
        for &(i, j, _) in &entries {
            if i >= rows || j >= cols {
                return Err(Error::InvalidArgument(format!(
                    "entry ({i},{j}) out of bounds {rows}x{cols}"
                )));
            }
        }
        entries.sort_unstable_by_key(|&(i, j, _)| (j, i));
        let mut col_ptrs = vec![0usize; cols + 1];
        let mut row_indices: Vec<u32> = vec![];
        let mut values: Vec<f64> = vec![];
        let mut prev: Option<(usize, usize)> = None;
        for (i, j, v) in entries {
            if prev == Some((i, j)) {
                *values.last_mut().expect("dup follows a stored entry") += v;
                continue;
            }
            row_indices.push(i as u32);
            values.push(v);
            col_ptrs[j + 1] = row_indices.len();
            prev = Some((i, j));
        }
        // make col_ptrs cumulative (forward-fill columns with no entries)
        for j in 1..=cols {
            if col_ptrs[j] < col_ptrs[j - 1] {
                col_ptrs[j] = col_ptrs[j - 1];
            }
        }
        Ok(SparseMatrix { rows, cols, col_ptrs, row_indices, values })
    }

    /// Random sparse matrix with a target density (deterministic per seed).
    pub fn rand(rows: usize, cols: usize, density: f64, rng: &mut SplitMix64) -> SparseMatrix {
        let mut entries = vec![];
        // per-column expected count keeps generation O(nnz)
        let per_col = ((rows as f64 * density).ceil() as usize).max(1);
        for j in 0..cols {
            let mut seen = std::collections::BTreeSet::new();
            for _ in 0..per_col {
                seen.insert(rng.next_usize(rows));
            }
            for i in seen {
                entries.push((i, j, rng.normal()));
            }
        }
        SparseMatrix::from_coo(rows, cols, entries).expect("in-bounds by construction")
    }

    /// Stored nonzero count.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// y = A x (dense x). CCS iterates columns, scattering into y —
    /// the §4.2 "Sparse Matrix × Dense Vector" kernel.
    pub fn spmv(&self, x: &Vector) -> Result<Vector> {
        crate::ensure_dims!(self.cols, x.len(), "spmv cols vs x");
        let mut y = vec![0.0; self.rows];
        for j in 0..self.cols {
            let xj = x[j];
            if xj == 0.0 {
                continue;
            }
            for p in self.col_ptrs[j]..self.col_ptrs[j + 1] {
                y[self.row_indices[p] as usize] += self.values[p] * xj;
            }
        }
        Ok(Vector(y))
    }

    /// y = Aᵀ x. CCS makes the transposed product a per-column *gather*
    /// (dot of column j with x) — no scatter, cache-friendly.
    pub fn spmv_t(&self, x: &Vector) -> Result<Vector> {
        crate::ensure_dims!(self.rows, x.len(), "spmv_t rows vs x");
        let mut y = vec![0.0; self.cols];
        for (j, yj) in y.iter_mut().enumerate() {
            let mut acc = 0.0;
            for p in self.col_ptrs[j]..self.col_ptrs[j + 1] {
                acc += self.values[p] * x[self.row_indices[p] as usize];
            }
            *yj = acc;
        }
        Ok(Vector(y))
    }

    /// C = A B for dense B — §4.2 "Sparse × Dense Matrix".
    pub fn spmm(&self, b: &DenseMatrix) -> Result<DenseMatrix> {
        crate::ensure_dims!(self.cols, b.rows, "spmm inner dims");
        let mut c = DenseMatrix::zeros(self.rows, b.cols);
        for j in 0..self.cols {
            let brow = b.row(j);
            for p in self.col_ptrs[j]..self.col_ptrs[j + 1] {
                let i = self.row_indices[p] as usize;
                let v = self.values[p];
                let crow = c.row_mut(i);
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += v * bv;
                }
            }
        }
        Ok(c)
    }

    /// C = Aᵀ B for dense B.
    pub fn spmm_t(&self, b: &DenseMatrix) -> Result<DenseMatrix> {
        crate::ensure_dims!(self.rows, b.rows, "spmm_t inner dims");
        let mut c = DenseMatrix::zeros(self.cols, b.cols);
        for j in 0..self.cols {
            let crow = c.row_mut(j);
            for p in self.col_ptrs[j]..self.col_ptrs[j + 1] {
                let i = self.row_indices[p] as usize;
                let v = self.values[p];
                for (cv, &bv) in crow.iter_mut().zip(b.row(i)) {
                    *cv += v * bv;
                }
            }
        }
        Ok(c)
    }

    /// Densify (test helper; O(rows*cols)).
    pub fn to_dense(&self) -> DenseMatrix {
        let mut m = DenseMatrix::zeros(self.rows, self.cols);
        for j in 0..self.cols {
            for p in self.col_ptrs[j]..self.col_ptrs[j + 1] {
                m.set(self.row_indices[p] as usize, j, self.values[p]);
            }
        }
        m
    }

    /// Iterate stored entries as (row, col, value).
    pub fn iter_entries(&self) -> impl Iterator<Item = (usize, usize, f64)> + '_ {
        (0..self.cols).flat_map(move |j| {
            (self.col_ptrs[j]..self.col_ptrs[j + 1])
                .map(move |p| (self.row_indices[p] as usize, j, self.values[p]))
        })
    }
}

/// CSR (Compressed Sparse Row) matrix: `row_ptrs` of length `rows + 1`;
/// `col_indices[row_ptrs[i]..row_ptrs[i+1]]` are the sorted column
/// indices of row i. The matvec direction: `y += A·x` walks each row
/// once as a gather — sequential reads, one sequential write per row.
#[derive(Debug, Clone, PartialEq)]
pub struct CsrMatrix {
    /// Rows.
    pub rows: usize,
    /// Cols.
    pub cols: usize,
    /// Row pointers, len rows+1.
    pub row_ptrs: Vec<usize>,
    /// Column index per stored value.
    pub col_indices: Vec<u32>,
    /// Stored values.
    pub values: Vec<f64>,
}

impl CsrMatrix {
    /// From COO triplets (unsorted ok; duplicates summed).
    pub fn from_coo(rows: usize, cols: usize, mut entries: Vec<(usize, usize, f64)>) -> Result<CsrMatrix> {
        for &(i, j, _) in &entries {
            if i >= rows || j >= cols {
                return Err(Error::InvalidArgument(format!(
                    "entry ({i},{j}) out of bounds {rows}x{cols}"
                )));
            }
        }
        entries.sort_unstable_by_key(|&(i, j, _)| (i, j));
        let mut row_ptrs = vec![0usize; rows + 1];
        let mut col_indices: Vec<u32> = vec![];
        let mut values: Vec<f64> = vec![];
        let mut prev: Option<(usize, usize)> = None;
        for (i, j, v) in entries {
            if prev == Some((i, j)) {
                *values.last_mut().expect("dup follows a stored entry") += v;
                continue;
            }
            col_indices.push(j as u32);
            values.push(v);
            row_ptrs[i + 1] = col_indices.len();
            prev = Some((i, j));
        }
        for i in 1..=rows {
            if row_ptrs[i] < row_ptrs[i - 1] {
                row_ptrs[i] = row_ptrs[i - 1];
            }
        }
        Ok(CsrMatrix { rows, cols, row_ptrs, col_indices, values })
    }

    /// From a dense matrix, dropping zeros.
    pub fn from_dense(a: &DenseMatrix) -> CsrMatrix {
        let mut entries = vec![];
        for i in 0..a.rows {
            for (j, &v) in a.row(i).iter().enumerate() {
                if v != 0.0 {
                    entries.push((i, j, v));
                }
            }
        }
        CsrMatrix::from_coo(a.rows, a.cols, entries).expect("in-bounds by construction")
    }

    /// Stored nonzero count.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Fraction of cells stored (`nnz / (rows·cols)`).
    pub fn density(&self) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.rows as f64 * self.cols as f64)
    }

    /// `acc += A·x` — allocation-free accumulate kernel; `acc` is the
    /// caller's (typically pool-leased) buffer of length `rows`.
    pub fn spmv_into(&self, x: &[f64], acc: &mut [f64]) {
        debug_assert_eq!(x.len(), self.cols);
        debug_assert_eq!(acc.len(), self.rows);
        for i in 0..self.rows {
            let mut s = 0.0;
            for p in self.row_ptrs[i]..self.row_ptrs[i + 1] {
                s += self.values[p] * x[self.col_indices[p] as usize];
            }
            acc[i] += s;
        }
    }

    /// `acc += Aᵀ·y` — the adjoint from CSR is a per-row scatter into
    /// the n-length accumulator (CSC is the gather-friendly layout for
    /// this direction; this kernel exists for the Dual/CSR-only stores).
    pub fn rspmv_into(&self, y: &[f64], acc: &mut [f64]) {
        debug_assert_eq!(y.len(), self.rows);
        debug_assert_eq!(acc.len(), self.cols);
        for i in 0..self.rows {
            let alpha = y[i];
            if alpha == 0.0 {
                continue;
            }
            for p in self.row_ptrs[i]..self.row_ptrs[i + 1] {
                acc[self.col_indices[p] as usize] += alpha * self.values[p];
            }
        }
    }

    /// `C += A·B` for dense `B` (sparse×dense): each stored `a[i,k]`
    /// axpys B's row k into C's row i — row-major streaming on both
    /// dense operands.
    pub fn spmm_acc(&self, b: &DenseMatrix, c: &mut DenseMatrix) {
        debug_assert_eq!(self.cols, b.rows);
        debug_assert_eq!((c.rows, c.cols), (self.rows, b.cols));
        for i in 0..self.rows {
            let crow = c.row_mut(i);
            for p in self.row_ptrs[i]..self.row_ptrs[i + 1] {
                let k = self.col_indices[p] as usize;
                let v = self.values[p];
                for (cv, &bv) in crow.iter_mut().zip(b.row(k)) {
                    *cv += v * bv;
                }
            }
        }
    }

    /// Convert to CSC (counting transpose — O(nnz + rows + cols)).
    pub fn to_csc(&self) -> CscMatrix {
        let mut col_ptrs = vec![0usize; self.cols + 1];
        for &j in &self.col_indices {
            col_ptrs[j as usize + 1] += 1;
        }
        for j in 0..self.cols {
            col_ptrs[j + 1] += col_ptrs[j];
        }
        let mut row_indices = vec![0u32; self.nnz()];
        let mut values = vec![0.0; self.nnz()];
        let mut next = col_ptrs.clone();
        for i in 0..self.rows {
            for p in self.row_ptrs[i]..self.row_ptrs[i + 1] {
                let j = self.col_indices[p] as usize;
                let q = next[j];
                next[j] += 1;
                row_indices[q] = i as u32;
                values[q] = self.values[p];
            }
        }
        CscMatrix { rows: self.rows, cols: self.cols, col_ptrs, row_indices, values }
    }

    /// Transpose (swaps the roles of rows and columns; O(nnz)).
    pub fn transpose(&self) -> CsrMatrix {
        let t = self.to_csc();
        CsrMatrix {
            rows: self.cols,
            cols: self.rows,
            row_ptrs: t.col_ptrs,
            col_indices: t.row_indices,
            values: t.values,
        }
    }

    /// Scale every stored value.
    pub fn scale(&self, alpha: f64) -> CsrMatrix {
        CsrMatrix {
            rows: self.rows,
            cols: self.cols,
            row_ptrs: self.row_ptrs.clone(),
            col_indices: self.col_indices.clone(),
            values: self.values.iter().map(|v| v * alpha).collect(),
        }
    }

    /// Sum of squared stored values.
    pub fn frob_sq(&self) -> f64 {
        self.values.iter().map(|v| v * v).sum()
    }

    /// Densify (O(rows·cols)).
    pub fn to_dense(&self) -> DenseMatrix {
        let mut m = DenseMatrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            for p in self.row_ptrs[i]..self.row_ptrs[i + 1] {
                m.set(i, self.col_indices[p] as usize, self.values[p]);
            }
        }
        m
    }

    /// Iterate stored entries as (row, col, value), row-major.
    pub fn iter_entries(&self) -> impl Iterator<Item = (usize, usize, f64)> + '_ {
        (0..self.rows).flat_map(move |i| {
            (self.row_ptrs[i]..self.row_ptrs[i + 1])
                .map(move |p| (i, self.col_indices[p] as usize, self.values[p]))
        })
    }
}

/// CSC (Compressed Sparse Column) matrix — same layout as the CCS
/// [`SparseMatrix`] but paired with [`CsrMatrix`] for the distributed
/// engine's accumulate kernels. The rmatvec direction: `acc += Aᵀ·y`
/// walks each column once as a gather.
#[derive(Debug, Clone, PartialEq)]
pub struct CscMatrix {
    /// Rows.
    pub rows: usize,
    /// Cols.
    pub cols: usize,
    /// Column pointers, len cols+1.
    pub col_ptrs: Vec<usize>,
    /// Row index per stored value.
    pub row_indices: Vec<u32>,
    /// Stored values.
    pub values: Vec<f64>,
}

impl CscMatrix {
    /// From COO triplets (unsorted ok; duplicates summed).
    pub fn from_coo(rows: usize, cols: usize, entries: Vec<(usize, usize, f64)>) -> Result<CscMatrix> {
        let ccs = SparseMatrix::from_coo(rows, cols, entries)?;
        Ok(CscMatrix {
            rows: ccs.rows,
            cols: ccs.cols,
            col_ptrs: ccs.col_ptrs,
            row_indices: ccs.row_indices,
            values: ccs.values,
        })
    }

    /// Stored nonzero count.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// `acc += A·x` — per-column scatter (CSR is the gather-friendly
    /// layout for this direction).
    pub fn spmv_into(&self, x: &[f64], acc: &mut [f64]) {
        debug_assert_eq!(x.len(), self.cols);
        debug_assert_eq!(acc.len(), self.rows);
        for j in 0..self.cols {
            let xj = x[j];
            if xj == 0.0 {
                continue;
            }
            for p in self.col_ptrs[j]..self.col_ptrs[j + 1] {
                acc[self.row_indices[p] as usize] += self.values[p] * xj;
            }
        }
    }

    /// `acc += Aᵀ·y` — per-column gather, the layout's fast direction.
    pub fn rspmv_into(&self, y: &[f64], acc: &mut [f64]) {
        debug_assert_eq!(y.len(), self.rows);
        debug_assert_eq!(acc.len(), self.cols);
        for j in 0..self.cols {
            let mut s = 0.0;
            for p in self.col_ptrs[j]..self.col_ptrs[j + 1] {
                s += self.values[p] * y[self.row_indices[p] as usize];
            }
            acc[j] += s;
        }
    }

    /// `C += A·B` for dense `B` (sparse×dense from CSC): column k of A
    /// axpys B's row k into the C rows its entries touch.
    pub fn spmm_acc(&self, b: &DenseMatrix, c: &mut DenseMatrix) {
        debug_assert_eq!(self.cols, b.rows);
        debug_assert_eq!((c.rows, c.cols), (self.rows, b.cols));
        for k in 0..self.cols {
            let brow = b.row(k);
            for p in self.col_ptrs[k]..self.col_ptrs[k + 1] {
                let i = self.row_indices[p] as usize;
                let v = self.values[p];
                for (cv, &bv) in c.row_mut(i).iter_mut().zip(brow) {
                    *cv += v * bv;
                }
            }
        }
    }

    /// Convert to CSR (counting transpose — O(nnz + rows + cols)).
    pub fn to_csr(&self) -> CsrMatrix {
        let mut row_ptrs = vec![0usize; self.rows + 1];
        for &i in &self.row_indices {
            row_ptrs[i as usize + 1] += 1;
        }
        for i in 0..self.rows {
            row_ptrs[i + 1] += row_ptrs[i];
        }
        let mut col_indices = vec![0u32; self.nnz()];
        let mut values = vec![0.0; self.nnz()];
        let mut next = row_ptrs.clone();
        for j in 0..self.cols {
            for p in self.col_ptrs[j]..self.col_ptrs[j + 1] {
                let i = self.row_indices[p] as usize;
                let q = next[i];
                next[i] += 1;
                col_indices[q] = j as u32;
                values[q] = self.values[p];
            }
        }
        CsrMatrix { rows: self.rows, cols: self.cols, row_ptrs, col_indices, values }
    }

    /// Sum of squared stored values.
    pub fn frob_sq(&self) -> f64 {
        self.values.iter().map(|v| v * v).sum()
    }

    /// Densify (O(rows·cols)).
    pub fn to_dense(&self) -> DenseMatrix {
        let mut m = DenseMatrix::zeros(self.rows, self.cols);
        for j in 0..self.cols {
            for p in self.col_ptrs[j]..self.col_ptrs[j + 1] {
                m.set(self.row_indices[p] as usize, j, self.values[p]);
            }
        }
        m
    }
}

/// `C += A·B` with dense `A`, CSR `B` (dense×sparse): for each C row i,
/// every `a[i,k]` axpys B's sparse row k into C's row i — no column
/// scatter, C rows written sequentially.
pub fn spmm_acc_ds(a: &DenseMatrix, b: &CsrMatrix, c: &mut DenseMatrix) {
    debug_assert_eq!(a.cols, b.rows);
    debug_assert_eq!((c.rows, c.cols), (a.rows, b.cols));
    for i in 0..a.rows {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for (k, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            for p in b.row_ptrs[k]..b.row_ptrs[k + 1] {
                crow[b.col_indices[p] as usize] += aik * b.values[p];
            }
        }
    }
}

/// `C += A·B` with CSR `A` and CSR `B` (sparse×sparse, dense
/// accumulator) — Gustavson's algorithm with C's dense row as the
/// scatter workspace.
pub fn spmm_acc_ss(a: &CsrMatrix, b: &CsrMatrix, c: &mut DenseMatrix) {
    debug_assert_eq!(a.cols, b.rows);
    debug_assert_eq!((c.rows, c.cols), (a.rows, b.cols));
    for i in 0..a.rows {
        let crow = c.row_mut(i);
        for p in a.row_ptrs[i]..a.row_ptrs[i + 1] {
            let k = a.col_indices[p] as usize;
            let va = a.values[p];
            for q in b.row_ptrs[k]..b.row_ptrs[k + 1] {
                crow[b.col_indices[q] as usize] += va * b.values[q];
            }
        }
    }
}

mod memory_impls {
    use super::{CscMatrix, CsrMatrix, SparseVector};
    use crate::error::{Error, Result};
    use crate::rdd::memory::{SizeOf, Spill};

    impl SizeOf for SparseVector {
        fn heap_bytes(&self) -> usize {
            self.indices.heap_bytes() + self.values.heap_bytes()
        }
    }

    impl Spill for SparseVector {
        fn encode(&self, out: &mut Vec<u8>) {
            self.size.encode(out);
            self.indices.encode(out);
            self.values.encode(out);
        }

        fn decode(src: &mut &[u8]) -> Result<Self> {
            let size = usize::decode(src)?;
            let indices = Vec::<u32>::decode(src)?;
            let values = Vec::<f64>::decode(src)?;
            if indices.len() != values.len() {
                return Err(Error::msg("spill decode: SparseVector arity mismatch"));
            }
            Ok(SparseVector { size, indices, values })
        }
    }

    impl SizeOf for CsrMatrix {
        fn heap_bytes(&self) -> usize {
            self.row_ptrs.heap_bytes() + self.col_indices.heap_bytes() + self.values.heap_bytes()
        }
    }

    impl Spill for CsrMatrix {
        fn encode(&self, out: &mut Vec<u8>) {
            self.rows.encode(out);
            self.cols.encode(out);
            self.row_ptrs.encode(out);
            self.col_indices.encode(out);
            self.values.encode(out);
        }

        fn decode(src: &mut &[u8]) -> Result<Self> {
            let rows = usize::decode(src)?;
            let cols = usize::decode(src)?;
            let row_ptrs = Vec::<usize>::decode(src)?;
            let col_indices = Vec::<u32>::decode(src)?;
            let values = Vec::<f64>::decode(src)?;
            if row_ptrs.len() != rows + 1 || col_indices.len() != values.len() {
                return Err(Error::msg("spill decode: CsrMatrix shape mismatch"));
            }
            Ok(CsrMatrix { rows, cols, row_ptrs, col_indices, values })
        }
    }

    impl SizeOf for CscMatrix {
        fn heap_bytes(&self) -> usize {
            self.col_ptrs.heap_bytes() + self.row_indices.heap_bytes() + self.values.heap_bytes()
        }
    }

    impl Spill for CscMatrix {
        fn encode(&self, out: &mut Vec<u8>) {
            self.rows.encode(out);
            self.cols.encode(out);
            self.col_ptrs.encode(out);
            self.row_indices.encode(out);
            self.values.encode(out);
        }

        fn decode(src: &mut &[u8]) -> Result<Self> {
            let rows = usize::decode(src)?;
            let cols = usize::decode(src)?;
            let col_ptrs = Vec::<usize>::decode(src)?;
            let row_indices = Vec::<u32>::decode(src)?;
            let values = Vec::<f64>::decode(src)?;
            if col_ptrs.len() != cols + 1 || row_indices.len() != values.len() {
                return Err(Error::msg("spill decode: CscMatrix shape mismatch"));
            }
            Ok(CscMatrix { rows, cols, col_ptrs, row_indices, values })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_allclose, check};

    #[test]
    fn sparse_vector_roundtrip() {
        let d = [1.0, 0.0, 3.0, 0.0];
        let s = SparseVector::from_dense(&d);
        assert_eq!(s.nnz(), 2);
        assert_eq!(s.indices, vec![0, 2]);
        assert_eq!(s.to_dense().0, d.to_vec());
    }

    #[test]
    fn sparse_vector_validation() {
        assert!(SparseVector::new(3, vec![0, 0], vec![1.0, 2.0]).is_err()); // dup
        assert!(SparseVector::new(3, vec![2, 1], vec![1.0, 2.0]).is_err()); // unsorted
        assert!(SparseVector::new(3, vec![3], vec![1.0]).is_err()); // oob
        assert!(SparseVector::new(3, vec![1], vec![]).is_err()); // arity
    }

    #[test]
    fn sparse_dot_matches_dense() {
        let s = SparseVector::from_dense(&[1.0, 0.0, -2.0, 0.0, 5.0]);
        let d = Vector::from(&[2.0, 9.0, 3.0, 9.0, 1.0]);
        assert_eq!(s.dot_dense(&d), 2.0 - 6.0 + 5.0);
    }

    #[test]
    fn coo_roundtrip_and_empty_columns() {
        let m = SparseMatrix::from_coo(3, 4, vec![(0, 0, 1.0), (2, 0, 2.0), (1, 3, 3.0)]).unwrap();
        assert_eq!(m.nnz(), 3);
        let d = m.to_dense();
        assert_eq!(d.get(0, 0), 1.0);
        assert_eq!(d.get(2, 0), 2.0);
        assert_eq!(d.get(1, 3), 3.0);
        assert_eq!(d.get(1, 1), 0.0);
        // col 1 and 2 empty
        assert_eq!(m.col_ptrs, vec![0, 2, 2, 2, 3]);
    }

    #[test]
    fn coo_out_of_bounds_rejected() {
        assert!(SparseMatrix::from_coo(2, 2, vec![(2, 0, 1.0)]).is_err());
    }

    #[test]
    fn coo_duplicates_summed() {
        let m = SparseMatrix::from_coo(
            3,
            3,
            vec![(1, 1, 1.0), (1, 1, 2.0), (1, 1, 4.0), (0, 2, 1.0), (0, 2, -1.0)],
        )
        .unwrap();
        assert_eq!(m.to_dense().get(1, 1), 7.0);
        assert_eq!(m.to_dense().get(0, 2), 0.0); // stored explicit zero
        assert_eq!(m.nnz(), 2);
    }

    #[test]
    fn spmv_matches_dense_property() {
        check("spmv == dense matvec", 30, |g| {
            let r = g.int(1, 20);
            let c = g.int(1, 15);
            let m = SparseMatrix::rand(r, c, 0.3, g.rng());
            let x = Vector((0..c).map(|_| g.normal()).collect());
            let ys = m.spmv(&x).unwrap();
            let yd = m.to_dense().matvec(&x).unwrap();
            assert_allclose(&ys.0, &yd.0, 1e-10, "spmv");
        });
    }

    #[test]
    fn spmv_t_matches_dense_property() {
        check("spmv_t == dense transpose matvec", 30, |g| {
            let r = g.int(1, 20);
            let c = g.int(1, 15);
            let m = SparseMatrix::rand(r, c, 0.3, g.rng());
            let x = Vector((0..r).map(|_| g.normal()).collect());
            let ys = m.spmv_t(&x).unwrap();
            let yd = m.to_dense().tmatvec(&x).unwrap();
            assert_allclose(&ys.0, &yd.0, 1e-10, "spmv_t");
        });
    }

    #[test]
    fn spmm_and_spmm_t_match_dense_property() {
        check("spmm == dense matmul", 20, |g| {
            let r = g.int(1, 12);
            let c = g.int(1, 10);
            let k = g.int(1, 8);
            let m = SparseMatrix::rand(r, c, 0.4, g.rng());
            let b = DenseMatrix::randn(c, k, g.rng());
            let got = m.spmm(&b).unwrap();
            let want = m.to_dense().matmul(&b).unwrap();
            assert_allclose(&got.data, &want.data, 1e-10, "spmm");

            let bt = DenseMatrix::randn(r, k, g.rng());
            let got_t = m.spmm_t(&bt).unwrap();
            let want_t = m.to_dense().transpose().matmul(&bt).unwrap();
            assert_allclose(&got_t.data, &want_t.data, 1e-10, "spmm_t");
        });
    }

    #[test]
    fn iter_entries_sorted_by_column() {
        let m = SparseMatrix::rand(10, 6, 0.3, &mut SplitMix64::new(5));
        let entries: Vec<_> = m.iter_entries().collect();
        assert_eq!(entries.len(), m.nnz());
        for w in entries.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
    }

    #[test]
    fn dim_mismatches_rejected() {
        let m = SparseMatrix::rand(4, 3, 0.5, &mut SplitMix64::new(6));
        assert!(m.spmv(&Vector::zeros(4)).is_err());
        assert!(m.spmv_t(&Vector::zeros(3)).is_err());
        assert!(m.spmm(&DenseMatrix::zeros(4, 2)).is_err());
    }

    // ------------------------------------------------- CSR/CSC kernels

    fn random_csr(g: &mut crate::util::prop::Gen, r: usize, c: usize, density: f64) -> CsrMatrix {
        let ccs = SparseMatrix::rand(r, c, density, g.rng());
        let entries: Vec<_> = ccs.iter_entries().collect();
        CsrMatrix::from_coo(r, c, entries).unwrap()
    }

    #[test]
    fn csr_csc_roundtrip_and_dense_agree() {
        check("csr <-> csc <-> dense roundtrip", 20, |g| {
            let r = 1 + g.int(0, 20);
            let c = 1 + g.int(0, 15);
            let a = random_csr(g, r, c, 0.3);
            let d = a.to_dense();
            assert_eq!(a.to_csc().to_dense().data, d.data, "csc densify");
            assert_eq!(a.to_csc().to_csr(), a, "csc->csr roundtrip");
            assert_eq!(CsrMatrix::from_dense(&d).to_dense().data, d.data, "from_dense");
            assert_eq!(a.transpose().to_dense().data, d.transpose().data, "transpose");
        });
    }

    #[test]
    fn csr_csc_spmv_kernels_match_dense_property() {
        check("csr/csc spmv_into + rspmv_into == dense", 25, |g| {
            let r = 1 + g.int(0, 20);
            let c = 1 + g.int(0, 15);
            let a = random_csr(g, r, c, 0.3);
            let csc = a.to_csc();
            let d = a.to_dense();
            let x = Vector((0..c).map(|_| g.normal()).collect());
            let y = Vector((0..r).map(|_| g.normal()).collect());
            let want_mv = d.matvec(&x).unwrap();
            let want_rv = d.tmatvec(&y).unwrap();
            let mut acc = vec![0.0; r];
            a.spmv_into(&x.0, &mut acc);
            assert_allclose(&acc, &want_mv.0, 1e-12, "csr spmv_into");
            let mut acc2 = vec![0.0; r];
            csc.spmv_into(&x.0, &mut acc2);
            assert_allclose(&acc2, &want_mv.0, 1e-12, "csc spmv_into");
            let mut acc3 = vec![0.0; c];
            a.rspmv_into(&y.0, &mut acc3);
            assert_allclose(&acc3, &want_rv.0, 1e-12, "csr rspmv_into");
            let mut acc4 = vec![0.0; c];
            csc.rspmv_into(&y.0, &mut acc4);
            assert_allclose(&acc4, &want_rv.0, 1e-12, "csc rspmv_into");
            // kernels accumulate: a second application doubles the result
            a.spmv_into(&x.0, &mut acc);
            let doubled: Vec<f64> = want_mv.0.iter().map(|v| 2.0 * v).collect();
            assert_allclose(&acc, &doubled, 1e-12, "csr spmv accumulates");
        });
    }

    #[test]
    fn spmm_acc_family_matches_dense_property() {
        check("spmm_acc sd/ds/ss == dense matmul", 20, |g| {
            let m = 1 + g.int(0, 12);
            let k = 1 + g.int(0, 10);
            let n = 1 + g.int(0, 8);
            let a = random_csr(g, m, k, 0.4);
            let b = random_csr(g, k, n, 0.4);
            let ad = a.to_dense();
            let bd = b.to_dense();
            let want = ad.matmul(&bd).unwrap();
            let mut c1 = DenseMatrix::zeros(m, n);
            a.spmm_acc(&bd, &mut c1);
            assert_allclose(&c1.data, &want.data, 1e-12, "csr spmm_acc (sparse×dense)");
            let mut c2 = DenseMatrix::zeros(m, n);
            a.to_csc().spmm_acc(&bd, &mut c2);
            assert_allclose(&c2.data, &want.data, 1e-12, "csc spmm_acc (sparse×dense)");
            let mut c3 = DenseMatrix::zeros(m, n);
            spmm_acc_ds(&ad, &b, &mut c3);
            assert_allclose(&c3.data, &want.data, 1e-12, "spmm_acc_ds (dense×sparse)");
            let mut c4 = DenseMatrix::zeros(m, n);
            spmm_acc_ss(&a, &b, &mut c4);
            assert_allclose(&c4.data, &want.data, 1e-12, "spmm_acc_ss (sparse×sparse)");
            // accumulation on a nonzero C
            let mut c5 = want.clone();
            spmm_acc_ss(&a, &b, &mut c5);
            let doubled: Vec<f64> = want.data.iter().map(|v| 2.0 * v).collect();
            assert_allclose(&c5.data, &doubled, 1e-12, "spmm_acc accumulates");
        });
    }

    #[test]
    fn csr_handles_empty_rows_and_columns() {
        // rows 1 and 3 empty, column 0 and 3 empty
        let a = CsrMatrix::from_coo(4, 4, vec![(0, 1, 2.0), (2, 2, -3.0)]).unwrap();
        assert_eq!(a.row_ptrs, vec![0, 1, 1, 2, 2]);
        let x = [1.0, 1.0, 1.0, 1.0];
        let mut acc = vec![0.0; 4];
        a.spmv_into(&x, &mut acc);
        assert_eq!(acc, vec![2.0, 0.0, -3.0, 0.0]);
        let csc = a.to_csc();
        assert_eq!(csc.col_ptrs, vec![0, 0, 1, 2, 2]);
        let mut racc = vec![0.0; 4];
        csc.rspmv_into(&x, &mut racc);
        assert_eq!(racc, vec![0.0, 2.0, -3.0, 0.0]);
        // fully empty matrix is fine
        let e = CsrMatrix::from_coo(3, 2, vec![]).unwrap();
        assert_eq!(e.nnz(), 0);
        let mut acc = vec![0.0; 3];
        e.spmv_into(&[0.0, 0.0], &mut acc);
        assert_eq!(acc, vec![0.0; 3]);
    }

    #[test]
    fn csr_duplicates_summed_and_bounds_checked() {
        let a = CsrMatrix::from_coo(2, 2, vec![(0, 0, 1.0), (0, 0, 2.5), (1, 1, -1.0)]).unwrap();
        assert_eq!(a.nnz(), 2);
        assert_eq!(a.to_dense().get(0, 0), 3.5);
        assert!(CsrMatrix::from_coo(2, 2, vec![(2, 0, 1.0)]).is_err());
        assert!(CscMatrix::from_coo(2, 2, vec![(0, 2, 1.0)]).is_err());
    }
}
