//! Sparse local types: `SparseVector` (parallel index/value arrays, the
//! paper's §2.4 format) and `SparseMatrix` in CCS (Compressed Column
//! Storage, §4.2), with the specialized kernels the paper benchmarks:
//! Sparse×DenseVector and Sparse×DenseMatrix, optionally transposed.

use crate::error::{Error, Result};
use crate::linalg::matrix::DenseMatrix;
use crate::linalg::vector::Vector;
use crate::util::rng::SplitMix64;

/// Sparse vector: sorted `indices` with matching `values` (paper §2.4:
/// "(3, [0, 2], [1.0, 3.0])").
#[derive(Debug, Clone, PartialEq)]
pub struct SparseVector {
    /// Logical length.
    pub size: usize,
    /// Sorted nonzero indices.
    pub indices: Vec<u32>,
    /// Values parallel to `indices`.
    pub values: Vec<f64>,
}

impl SparseVector {
    /// Build, validating sortedness and bounds.
    pub fn new(size: usize, indices: Vec<u32>, values: Vec<f64>) -> Result<SparseVector> {
        if indices.len() != values.len() {
            return Err(Error::dim(format!(
                "sparse vector: {} indices vs {} values",
                indices.len(),
                values.len()
            )));
        }
        for w in indices.windows(2) {
            if w[0] >= w[1] {
                return Err(Error::InvalidArgument("indices must be strictly increasing".into()));
            }
        }
        if let Some(&last) = indices.last() {
            if last as usize >= size {
                return Err(Error::InvalidArgument(format!("index {last} >= size {size}")));
            }
        }
        Ok(SparseVector { size, indices, values })
    }

    /// From a dense slice, dropping zeros.
    pub fn from_dense(xs: &[f64]) -> SparseVector {
        let mut indices = vec![];
        let mut values = vec![];
        for (i, &x) in xs.iter().enumerate() {
            if x != 0.0 {
                indices.push(i as u32);
                values.push(x);
            }
        }
        SparseVector { size: xs.len(), indices, values }
    }

    /// Densify.
    pub fn to_dense(&self) -> Vector {
        let mut v = vec![0.0; self.size];
        for (&i, &x) in self.indices.iter().zip(&self.values) {
            v[i as usize] = x;
        }
        Vector(v)
    }

    /// Number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Dot with a dense vector.
    pub fn dot_dense(&self, d: &Vector) -> f64 {
        debug_assert_eq!(self.size, d.len());
        self.indices
            .iter()
            .zip(&self.values)
            .map(|(&i, &x)| x * d[i as usize])
            .sum()
    }

    /// Squared 2-norm.
    pub fn norm2_sq(&self) -> f64 {
        self.values.iter().map(|x| x * x).sum()
    }
}

/// CCS sparse matrix (MLlib `SparseMatrix`): `col_ptrs` of length
/// `cols + 1`; `row_indices[col_ptrs[j]..col_ptrs[j+1]]` are the (sorted)
/// row indices of column j.
#[derive(Debug, Clone, PartialEq)]
pub struct SparseMatrix {
    /// Rows.
    pub rows: usize,
    /// Cols.
    pub cols: usize,
    /// Column pointers, len cols+1.
    pub col_ptrs: Vec<usize>,
    /// Row index per stored value.
    pub row_indices: Vec<u32>,
    /// Stored values.
    pub values: Vec<f64>,
}

impl SparseMatrix {
    /// From COO triplets (unsorted ok; duplicates summed).
    pub fn from_coo(rows: usize, cols: usize, mut entries: Vec<(usize, usize, f64)>) -> Result<SparseMatrix> {
        for &(i, j, _) in &entries {
            if i >= rows || j >= cols {
                return Err(Error::InvalidArgument(format!(
                    "entry ({i},{j}) out of bounds {rows}x{cols}"
                )));
            }
        }
        entries.sort_unstable_by_key(|&(i, j, _)| (j, i));
        let mut col_ptrs = vec![0usize; cols + 1];
        let mut row_indices: Vec<u32> = vec![];
        let mut values: Vec<f64> = vec![];
        let mut prev: Option<(usize, usize)> = None;
        for (i, j, v) in entries {
            if prev == Some((i, j)) {
                *values.last_mut().expect("dup follows a stored entry") += v;
                continue;
            }
            row_indices.push(i as u32);
            values.push(v);
            col_ptrs[j + 1] = row_indices.len();
            prev = Some((i, j));
        }
        // make col_ptrs cumulative (forward-fill columns with no entries)
        for j in 1..=cols {
            if col_ptrs[j] < col_ptrs[j - 1] {
                col_ptrs[j] = col_ptrs[j - 1];
            }
        }
        Ok(SparseMatrix { rows, cols, col_ptrs, row_indices, values })
    }

    /// Random sparse matrix with a target density (deterministic per seed).
    pub fn rand(rows: usize, cols: usize, density: f64, rng: &mut SplitMix64) -> SparseMatrix {
        let mut entries = vec![];
        // per-column expected count keeps generation O(nnz)
        let per_col = ((rows as f64 * density).ceil() as usize).max(1);
        for j in 0..cols {
            let mut seen = std::collections::BTreeSet::new();
            for _ in 0..per_col {
                seen.insert(rng.next_usize(rows));
            }
            for i in seen {
                entries.push((i, j, rng.normal()));
            }
        }
        SparseMatrix::from_coo(rows, cols, entries).expect("in-bounds by construction")
    }

    /// Stored nonzero count.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// y = A x (dense x). CCS iterates columns, scattering into y —
    /// the §4.2 "Sparse Matrix × Dense Vector" kernel.
    pub fn spmv(&self, x: &Vector) -> Result<Vector> {
        crate::ensure_dims!(self.cols, x.len(), "spmv cols vs x");
        let mut y = vec![0.0; self.rows];
        for j in 0..self.cols {
            let xj = x[j];
            if xj == 0.0 {
                continue;
            }
            for p in self.col_ptrs[j]..self.col_ptrs[j + 1] {
                y[self.row_indices[p] as usize] += self.values[p] * xj;
            }
        }
        Ok(Vector(y))
    }

    /// y = Aᵀ x. CCS makes the transposed product a per-column *gather*
    /// (dot of column j with x) — no scatter, cache-friendly.
    pub fn spmv_t(&self, x: &Vector) -> Result<Vector> {
        crate::ensure_dims!(self.rows, x.len(), "spmv_t rows vs x");
        let mut y = vec![0.0; self.cols];
        for (j, yj) in y.iter_mut().enumerate() {
            let mut acc = 0.0;
            for p in self.col_ptrs[j]..self.col_ptrs[j + 1] {
                acc += self.values[p] * x[self.row_indices[p] as usize];
            }
            *yj = acc;
        }
        Ok(Vector(y))
    }

    /// C = A B for dense B — §4.2 "Sparse × Dense Matrix".
    pub fn spmm(&self, b: &DenseMatrix) -> Result<DenseMatrix> {
        crate::ensure_dims!(self.cols, b.rows, "spmm inner dims");
        let mut c = DenseMatrix::zeros(self.rows, b.cols);
        for j in 0..self.cols {
            let brow = b.row(j);
            for p in self.col_ptrs[j]..self.col_ptrs[j + 1] {
                let i = self.row_indices[p] as usize;
                let v = self.values[p];
                let crow = c.row_mut(i);
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += v * bv;
                }
            }
        }
        Ok(c)
    }

    /// C = Aᵀ B for dense B.
    pub fn spmm_t(&self, b: &DenseMatrix) -> Result<DenseMatrix> {
        crate::ensure_dims!(self.rows, b.rows, "spmm_t inner dims");
        let mut c = DenseMatrix::zeros(self.cols, b.cols);
        for j in 0..self.cols {
            let crow = c.row_mut(j);
            for p in self.col_ptrs[j]..self.col_ptrs[j + 1] {
                let i = self.row_indices[p] as usize;
                let v = self.values[p];
                for (cv, &bv) in crow.iter_mut().zip(b.row(i)) {
                    *cv += v * bv;
                }
            }
        }
        Ok(c)
    }

    /// Densify (test helper; O(rows*cols)).
    pub fn to_dense(&self) -> DenseMatrix {
        let mut m = DenseMatrix::zeros(self.rows, self.cols);
        for j in 0..self.cols {
            for p in self.col_ptrs[j]..self.col_ptrs[j + 1] {
                m.set(self.row_indices[p] as usize, j, self.values[p]);
            }
        }
        m
    }

    /// Iterate stored entries as (row, col, value).
    pub fn iter_entries(&self) -> impl Iterator<Item = (usize, usize, f64)> + '_ {
        (0..self.cols).flat_map(move |j| {
            (self.col_ptrs[j]..self.col_ptrs[j + 1])
                .map(move |p| (self.row_indices[p] as usize, j, self.values[p]))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_allclose, check};

    #[test]
    fn sparse_vector_roundtrip() {
        let d = [1.0, 0.0, 3.0, 0.0];
        let s = SparseVector::from_dense(&d);
        assert_eq!(s.nnz(), 2);
        assert_eq!(s.indices, vec![0, 2]);
        assert_eq!(s.to_dense().0, d.to_vec());
    }

    #[test]
    fn sparse_vector_validation() {
        assert!(SparseVector::new(3, vec![0, 0], vec![1.0, 2.0]).is_err()); // dup
        assert!(SparseVector::new(3, vec![2, 1], vec![1.0, 2.0]).is_err()); // unsorted
        assert!(SparseVector::new(3, vec![3], vec![1.0]).is_err()); // oob
        assert!(SparseVector::new(3, vec![1], vec![]).is_err()); // arity
    }

    #[test]
    fn sparse_dot_matches_dense() {
        let s = SparseVector::from_dense(&[1.0, 0.0, -2.0, 0.0, 5.0]);
        let d = Vector::from(&[2.0, 9.0, 3.0, 9.0, 1.0]);
        assert_eq!(s.dot_dense(&d), 2.0 - 6.0 + 5.0);
    }

    #[test]
    fn coo_roundtrip_and_empty_columns() {
        let m = SparseMatrix::from_coo(3, 4, vec![(0, 0, 1.0), (2, 0, 2.0), (1, 3, 3.0)]).unwrap();
        assert_eq!(m.nnz(), 3);
        let d = m.to_dense();
        assert_eq!(d.get(0, 0), 1.0);
        assert_eq!(d.get(2, 0), 2.0);
        assert_eq!(d.get(1, 3), 3.0);
        assert_eq!(d.get(1, 1), 0.0);
        // col 1 and 2 empty
        assert_eq!(m.col_ptrs, vec![0, 2, 2, 2, 3]);
    }

    #[test]
    fn coo_out_of_bounds_rejected() {
        assert!(SparseMatrix::from_coo(2, 2, vec![(2, 0, 1.0)]).is_err());
    }

    #[test]
    fn coo_duplicates_summed() {
        let m = SparseMatrix::from_coo(
            3,
            3,
            vec![(1, 1, 1.0), (1, 1, 2.0), (1, 1, 4.0), (0, 2, 1.0), (0, 2, -1.0)],
        )
        .unwrap();
        assert_eq!(m.to_dense().get(1, 1), 7.0);
        assert_eq!(m.to_dense().get(0, 2), 0.0); // stored explicit zero
        assert_eq!(m.nnz(), 2);
    }

    #[test]
    fn spmv_matches_dense_property() {
        check("spmv == dense matvec", 30, |g| {
            let r = g.int(1, 20);
            let c = g.int(1, 15);
            let m = SparseMatrix::rand(r, c, 0.3, g.rng());
            let x = Vector((0..c).map(|_| g.normal()).collect());
            let ys = m.spmv(&x).unwrap();
            let yd = m.to_dense().matvec(&x).unwrap();
            assert_allclose(&ys.0, &yd.0, 1e-10, "spmv");
        });
    }

    #[test]
    fn spmv_t_matches_dense_property() {
        check("spmv_t == dense transpose matvec", 30, |g| {
            let r = g.int(1, 20);
            let c = g.int(1, 15);
            let m = SparseMatrix::rand(r, c, 0.3, g.rng());
            let x = Vector((0..r).map(|_| g.normal()).collect());
            let ys = m.spmv_t(&x).unwrap();
            let yd = m.to_dense().tmatvec(&x).unwrap();
            assert_allclose(&ys.0, &yd.0, 1e-10, "spmv_t");
        });
    }

    #[test]
    fn spmm_and_spmm_t_match_dense_property() {
        check("spmm == dense matmul", 20, |g| {
            let r = g.int(1, 12);
            let c = g.int(1, 10);
            let k = g.int(1, 8);
            let m = SparseMatrix::rand(r, c, 0.4, g.rng());
            let b = DenseMatrix::randn(c, k, g.rng());
            let got = m.spmm(&b).unwrap();
            let want = m.to_dense().matmul(&b).unwrap();
            assert_allclose(&got.data, &want.data, 1e-10, "spmm");

            let bt = DenseMatrix::randn(r, k, g.rng());
            let got_t = m.spmm_t(&bt).unwrap();
            let want_t = m.to_dense().transpose().matmul(&bt).unwrap();
            assert_allclose(&got_t.data, &want_t.data, 1e-10, "spmm_t");
        });
    }

    #[test]
    fn iter_entries_sorted_by_column() {
        let m = SparseMatrix::rand(10, 6, 0.3, &mut SplitMix64::new(5));
        let entries: Vec<_> = m.iter_entries().collect();
        assert_eq!(entries.len(), m.nnz());
        for w in entries.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
    }

    #[test]
    fn dim_mismatches_rejected() {
        let m = SparseMatrix::rand(4, 3, 0.5, &mut SplitMix64::new(6));
        assert!(m.spmv(&Vector::zeros(4)).is_err());
        assert!(m.spmv_t(&Vector::zeros(3)).is_err());
        assert!(m.spmm(&DenseMatrix::zeros(4, 2)).is_err());
    }
}
