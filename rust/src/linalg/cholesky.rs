//! Cholesky factorization + triangular solves — driver-side tools used by
//! the TSQR R-factor path and the smoothed-LP dual recovery.

use crate::error::{Error, Result};
use crate::linalg::matrix::DenseMatrix;
use crate::linalg::vector::Vector;

/// Lower-triangular L with A = L Lᵀ. Errors if A is not (numerically) PD.
pub fn cholesky(a: &DenseMatrix) -> Result<DenseMatrix> {
    let n = a.rows;
    if a.cols != n {
        return Err(Error::dim(format!("cholesky needs square, got {}x{}", a.rows, a.cols)));
    }
    let mut l = DenseMatrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.get(i, j);
            for k in 0..j {
                sum -= l.get(i, k) * l.get(j, k);
            }
            if i == j {
                if sum <= 0.0 {
                    return Err(Error::InvalidArgument(format!(
                        "cholesky: pivot {i} non-positive ({sum:.3e}) — matrix not PD"
                    )));
                }
                l.set(i, j, sum.sqrt());
            } else {
                l.set(i, j, sum / l.get(j, j));
            }
        }
    }
    Ok(l)
}

/// Solve L x = b with L lower triangular (forward substitution).
pub fn solve_lower(l: &DenseMatrix, b: &Vector) -> Result<Vector> {
    let n = l.rows;
    crate::ensure_dims!(n, b.len(), "solve_lower dims");
    let mut x = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for j in 0..i {
            s -= l.get(i, j) * x[j];
        }
        let d = l.get(i, i);
        if d.abs() < 1e-300 {
            return Err(Error::InvalidArgument(format!("solve_lower: zero pivot at {i}")));
        }
        x[i] = s / d;
    }
    Ok(Vector(x))
}

/// Solve U x = b with U upper triangular (back substitution).
pub fn solve_upper(u: &DenseMatrix, b: &Vector) -> Result<Vector> {
    let n = u.rows;
    crate::ensure_dims!(n, b.len(), "solve_upper dims");
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = b[i];
        for j in i + 1..n {
            s -= u.get(i, j) * x[j];
        }
        let d = u.get(i, i);
        if d.abs() < 1e-300 {
            return Err(Error::InvalidArgument(format!("solve_upper: zero pivot at {i}")));
        }
        x[i] = s / d;
    }
    Ok(Vector(x))
}

/// Solve A x = b for symmetric positive-definite A via Cholesky.
pub fn solve_spd(a: &DenseMatrix, b: &Vector) -> Result<Vector> {
    let l = cholesky(a)?;
    let y = solve_lower(&l, b)?;
    solve_upper(&l.transpose(), &y)
}

/// Invert an upper-triangular matrix (for TSQR's R⁻¹ when forming Q).
pub fn invert_upper(u: &DenseMatrix) -> Result<DenseMatrix> {
    let n = u.rows;
    let mut inv = DenseMatrix::zeros(n, n);
    for col in 0..n {
        let mut e = Vector::zeros(n);
        e[col] = 1.0;
        let x = solve_upper(u, &e)?;
        for i in 0..n {
            inv.set(i, col, x[i]);
        }
    }
    Ok(inv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_allclose, check};
    use crate::util::rng::SplitMix64;

    fn random_spd(n: usize, rng: &mut SplitMix64) -> DenseMatrix {
        let a = DenseMatrix::randn(n + 2, n, rng);
        let mut g = a.gram();
        for i in 0..n {
            g.set(i, i, g.get(i, i) + 0.5); // bump diagonal for conditioning
        }
        g
    }

    #[test]
    fn cholesky_reconstructs_property() {
        check("L L^T == A", 20, |g| {
            let n = g.int(1, 10);
            let a = random_spd(n, g.rng());
            let l = cholesky(&a).unwrap();
            let back = l.matmul(&l.transpose()).unwrap();
            assert!(back.max_abs_diff(&a) < 1e-8 * (1.0 + a.frob_norm()));
            // L is lower triangular
            for i in 0..n {
                for j in i + 1..n {
                    assert_eq!(l.get(i, j), 0.0);
                }
            }
        });
    }

    #[test]
    fn non_pd_rejected() {
        let a = DenseMatrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]).unwrap(); // eigs 3,-1
        assert!(cholesky(&a).is_err());
        assert!(cholesky(&DenseMatrix::zeros(2, 3)).is_err());
    }

    #[test]
    fn spd_solve_property() {
        check("solve_spd residual small", 20, |g| {
            let n = g.int(1, 10);
            let a = random_spd(n, g.rng());
            let b = Vector((0..n).map(|_| g.normal()).collect());
            let x = solve_spd(&a, &b).unwrap();
            let r = a.matvec(&x).unwrap().sub(&b);
            assert!(r.norm2() < 1e-7 * (1.0 + b.norm2()), "residual {}", r.norm2());
        });
    }

    #[test]
    fn triangular_solves() {
        let l = DenseMatrix::from_rows(&[vec![2.0, 0.0], vec![1.0, 3.0]]).unwrap();
        let x = solve_lower(&l, &Vector::from(&[4.0, 11.0])).unwrap();
        assert_allclose(&x.0, &[2.0, 3.0], 1e-12, "fwd");
        let u = l.transpose();
        let x = solve_upper(&u, &Vector::from(&[7.0, 9.0])).unwrap();
        assert_allclose(&x.0, &[2.0, 3.0], 1e-12, "bwd");
    }

    #[test]
    fn invert_upper_property() {
        check("U U^-1 == I", 15, |g| {
            let n = g.int(1, 8);
            let a = random_spd(n, g.rng());
            let l = cholesky(&a).unwrap();
            let u = l.transpose();
            let uinv = invert_upper(&u).unwrap();
            let eye = u.matmul(&uinv).unwrap();
            assert!(eye.max_abs_diff(&DenseMatrix::eye(n)) < 1e-8);
        });
    }

    #[test]
    fn zero_pivot_rejected() {
        let u = DenseMatrix::from_rows(&[vec![1.0, 1.0], vec![0.0, 0.0]]).unwrap();
        assert!(solve_upper(&u, &Vector::from(&[1.0, 1.0])).is_err());
    }
}
