//! Section 3.2.3: the smoothed linear program
//!     minimize c'x + 1/2||x - x0||^2  s.t.  Ax = b, x >= 0
//! solved through the Smoothed Conic Dual with continuation, on a
//! transportation-style problem with a distributed constraint matrix.
//!
//! ```bash
//! cargo run --release --example linear_program
//! ```

use sparkla::distributed::RowMatrix;
use sparkla::linalg::matrix::DenseMatrix;
use sparkla::linalg::vector::Vector;
use sparkla::tfocs::linop::LinopMatrix;
use sparkla::tfocs::lp::solve_lp_continued;
use sparkla::util::rng::SplitMix64;
use sparkla::Context;

fn main() -> sparkla::Result<()> {
    let ctx = Context::local("linear_program", 4);
    let mut rng = SplitMix64::new(17);

    // feasible-by-construction LP: 30 constraints x 120 variables
    let (nc, nv) = (30, 120);
    let a_local = DenseMatrix::randn(nc, nv, &mut rng);
    let x_feas = Vector((0..nv).map(|_| rng.next_f64()).collect());
    let b = a_local.matvec(&x_feas)?;
    let c = Vector((0..nv).map(|_| rng.next_f64() + 0.1).collect());

    let rm = RowMatrix::from_local(&ctx, &a_local, 4);
    let op = LinopMatrix::new(&rm)?;
    println!("smoothed LP: {nv} vars, {nc} equality constraints, x >= 0");
    let r = solve_lp_continued(&op, &b, &c, 400, 4)?;

    for (round, (obj, res)) in r.primal_objective.iter().zip(&r.residuals).enumerate() {
        println!("  continuation round {round}: c'x = {obj:.6}, ||Ax-b|| = {res:.3e}");
    }
    println!(
        "final: objective {:.6} (feasible upper bound {:.6}), {} linop applies",
        r.primal_objective.last().unwrap(),
        c.dot(&x_feas),
        r.linop_applies
    );
    let min_x = r.x.0.iter().cloned().fold(f64::INFINITY, f64::min);
    println!("min(x) = {min_x:.2e} (nonnegativity)");
    Ok(())
}
