//! Table-1-style SVD runs: Netflix-shaped sparse matrices, top-5 singular
//! values via the ARPACK reverse-communication path, reporting time per
//! iteration (= per distributed mat-vec) and total time like the paper.
//!
//! The paper's matrices are scaled to laptop RAM (scale factor printed);
//! the claim being reproduced is the *shape* of Table 1: per-iteration
//! time tracks nnz, totals stay within seconds at k=5.
//!
//! ```bash
//! cargo run --release --example svd_arpack [-- --scale 100]
//! ```

use sparkla::distributed::svd::arpack_svd;
use sparkla::distributed::CoordinateMatrix;
use sparkla::util::argparse::ArgSpec;
use sparkla::util::timer::Timer;
use sparkla::Context;

fn main() -> sparkla::Result<()> {
    let args = ArgSpec::new("svd_arpack", "Table 1 reproduction (scaled)")
        .opt("scale", "400", "divide the paper's matrix dimensions by this")
        .opt("k", "5", "singular triplets (paper: 5)")
        .opt("executors", "4", "logical executors")
        .parse();
    let scale = args.usize("scale").max(1);
    let k = args.usize("k");
    let ctx = Context::local("svd_arpack", args.usize("executors"));

    // Table 1 rows: (rows, cols, nnz) at paper scale
    let paper_rows: [(u64, u64, usize); 3] = [
        (23_000_000, 38_000, 51_000_000),
        (63_000_000, 49_000, 440_000_000),
        (94_000_000, 4_000, 1_600_000_000),
    ];
    println!("Table 1 reproduction at 1/{scale} scale, k={k}");
    println!(
        "{:<26} {:>12} {:>10} {:>14} {:>12}",
        "matrix", "nnz", "matvecs", "s/matvec", "total (s)"
    );
    for (pr, pc, pnnz) in paper_rows {
        let rows = (pr as usize / scale).max(100) as u64;
        let cols = (pc as usize / scale).max(20) as u64;
        // scale nnz by 1/s (not 1/s²): preserves nnz-per-row, the per-iteration
        // work driver that gives Table 1 its shape
        let nnz = (pnnz / scale).max(1000);
        let cm = CoordinateMatrix::sprand(&ctx, rows, cols, nnz, 16, 1);
        let rm = cm.to_row_matrix(16)?.cache();
        rm.gram()?; // warm the cache so timing isolates the solve (paper: data in RAM)
        let t = Timer::start();
        let svd = arpack_svd(&rm, k.min(cols as usize), false)?;
        let total = t.secs();
        println!(
            "{:<26} {:>12} {:>10} {:>14.4} {:>12.2}",
            format!("{rows}x{cols}"),
            nnz,
            svd.matrix_ops,
            total / svd.matrix_ops.max(1) as f64,
            total
        );
    }
    println!("\n(per-iteration time should increase with nnz — Table 1's shape)");
    Ok(())
}
