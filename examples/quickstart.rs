//! Quickstart: build distributed matrices, run the core computations.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use sparkla::distributed::{BlockMatrix, CoordinateMatrix, RowMatrix};
use sparkla::linalg::matrix::DenseMatrix;
use sparkla::util::rng::SplitMix64;
use sparkla::Context;

fn main() -> sparkla::Result<()> {
    // a local "cluster": 4 executors x 2 cores
    let ctx = Context::local("quickstart", 4);

    // ---- RowMatrix: column stats, Gram, SVD, PCA --------------------
    let mut rng = SplitMix64::new(7);
    let local = DenseMatrix::randn(5000, 24, &mut rng);
    let a = RowMatrix::from_local(&ctx, &local, 8).cache();
    println!("A: {} x {} ({} nonzeros)", a.num_rows()?, a.num_cols()?, a.nnz()?);

    let stats = a.column_stats()?;
    println!("col 0: mean={:+.4} std={:.4}", stats.mean()[0], stats.variance()[0].sqrt());

    let svd = a.compute_svd(5, true)?;
    println!("top-5 singular values ({}): {:?}", svd.algorithm, svd.s);
    let err = sparkla::distributed::svd::reconstruction_error(&a, &svd)?;
    println!("rank-5 reconstruction error: {err:.4}");

    let (_components, variances) = a.pca(3)?;
    println!("top-3 PCA explained variances: {variances:?}");

    // ---- CoordinateMatrix: operator-trait SVD, no conversion --------
    let cm = CoordinateMatrix::sprand(&ctx, 10_000, 100, 50_000, 8, 42).cache();
    println!("sparse C: {} x {}, nnz={}", cm.num_rows, cm.num_cols, cm.nnz()?);
    // the ARPACK driver only needs the trait's gramvec — the entries are
    // never shuffled into row form
    let sparse_svd = sparkla::distributed::svd::compute_svd(&cm, 5, false)?;
    println!(
        "sparse top-5 singular values ({}, {} distributed ops): {:?}",
        sparse_svd.algorithm, sparse_svd.matrix_ops, sparse_svd.s
    );

    // conversions are still there when a consumer wants a layout
    let c_rows = cm.to_row_matrix(8)?;
    let sims = c_rows.column_similarities(Some(0.1))?;
    println!("DIMSUM similarity (0,1) = {:+.4}", sims.get(0, 1));

    // ---- BlockMatrix: distributed multiply --------------------------
    let x = DenseMatrix::randn(96, 64, &mut rng);
    let y = DenseMatrix::randn(64, 48, &mut rng);
    let bx = BlockMatrix::from_local(&ctx, &x, 32, 32, 4);
    let by = BlockMatrix::from_local(&ctx, &y, 32, 32, 4);
    bx.validate()?;
    let product = bx.multiply(&by)?;
    let check = product.to_local()?.max_abs_diff(&x.matmul(&y)?);
    println!("BlockMatrix multiply vs local: max |diff| = {check:.2e}");

    println!("\nscheduler metrics: {}", ctx.metrics().summary());
    Ok(())
}
