//! Section 3.2.2: LASSO via the TFOCS composite template — the paper's
//! `SolverL1RLS(A, b, lambda)` example, on the paper's own synthetic
//! design (scaled test_LASSO.m data).
//!
//! ```bash
//! cargo run --release --example lasso_tfocs
//! ```

use sparkla::distributed::{CoordinateMatrix, RowMatrix};
use sparkla::linalg::matrix::DenseMatrix;
use sparkla::linalg::vector::Vector;
use sparkla::tfocs::solve_lasso;
use sparkla::util::rng::SplitMix64;
use sparkla::Context;

fn main() -> sparkla::Result<()> {
    let ctx = Context::local("lasso_tfocs", 4);
    let mut rng = SplitMix64::new(31);

    // planted sparse model: 1024 observations, 256 features, 16 active
    let (m, n, k_active) = (1024, 256, 16);
    let a_local = DenseMatrix::randn(m, n, &mut rng);
    let mut x_true = Vector::zeros(n);
    for idx in rng.sample_indices(n, k_active) {
        x_true[idx] = rng.normal() * 3.0;
    }
    let noise = Vector(rng.normal_vec(m)).scale(0.05);
    let b = a_local.matvec(&x_true)?.add(&noise);

    let a = RowMatrix::from_local(&ctx, &a_local, 8).cache();
    let lambda = 2.0;
    println!("solving LASSO: {m}x{n}, lambda={lambda} (composite: SmoothQuad ∘ LinopMatrix + ProxL1)");
    let r = solve_lasso(&a, &b, lambda, 500)?;

    let support: Vec<usize> = (0..n).filter(|&j| r.x[j].abs() > 1e-6).collect();
    let true_support: Vec<usize> = (0..n).filter(|&j| x_true[j] != 0.0).collect();
    let hits = support.iter().filter(|j| true_support.contains(j)).count();
    println!(
        "objective {:.4} -> {:.4} over {} iterations ({} linop applies, {} restarts)",
        r.objective[0],
        r.objective.last().unwrap(),
        r.objective.len() - 1,
        r.linop_applies,
        r.restarts
    );
    println!(
        "support: recovered {}/{} true actives, {} spurious",
        hits,
        true_support.len(),
        support.len() - hits
    );
    let rel = r.x.sub(&x_true).norm2() / x_true.norm2();
    println!("relative estimation error: {rel:.4}");

    // the same solve through the operator trait on entry storage — the
    // format the paper could not yet support ("Currently support is only
    // implemented for RDD[Vector] row matrices")
    let a_coo = CoordinateMatrix::from_local(&ctx, &a_local, 8);
    let r_coo = solve_lasso(&a_coo, &b, lambda, 500)?;
    println!(
        "coordinate-format solve (no row conversion): |x_row - x_coo| = {:.2e}",
        r_coo.x.sub(&r.x).norm2()
    );
    println!("cluster: {}", ctx.metrics().summary());
    Ok(())
}
