//! Figure 1 reproduction: error-per-iteration for the six optimization
//! primitives (gra, acc, acc_r, acc_b, acc_rb, lbfgs) on the paper's four
//! test problems:
//!
//!   linear      10000 obs x 1024 feats, 512 informative, unregularized LSQ
//!   linear l1   same, with L1 regularization
//!   logistic    10000 obs x 250 feats (category-gaussian features)
//!   logistic l2 same, with L2 regularization
//!
//! All methods share the same initial step size (paper protocol). Output:
//! ASCII log-error plots + CSV series under target/experiments/.
//!
//! ```bash
//! cargo run --release --example convergence_suite [-- --rows 10000 --iters 100]
//! ```

use sparkla::linalg::vector::Vector;
use sparkla::optim::accelerated::{accelerated, AccelConfig};
use sparkla::optim::gd::{gradient_descent, GdConfig};
use sparkla::optim::lbfgs::{lbfgs, LbfgsConfig};
use sparkla::optim::problem::{synth, DistProblem};
use sparkla::optim::{Regularizer, Trace};
use sparkla::util::argparse::ArgSpec;
use sparkla::util::csv::CsvWriter;
use sparkla::util::plot::{render, Series};
use sparkla::Context;

fn run_all(problem: &DistProblem, dim: usize, iters: usize, skip_lbfgs_l1: bool) -> Vec<Trace> {
    let w0 = Vector::zeros(dim);
    let step = 1.0 / problem.lipschitz_estimate().expect("lipschitz");
    let mut traces = vec![];
    traces.push(
        gradient_descent(problem, &w0, &GdConfig { step_size: step, max_iters: iters, tol: 0.0 })
            .expect("gra"),
    );
    for name in ["acc", "acc_r", "acc_b", "acc_rb"] {
        let cfg = AccelConfig::variant(name, step, iters).unwrap();
        traces.push(accelerated(problem, &w0, &cfg).expect(name));
    }
    if !skip_lbfgs_l1 {
        traces.push(
            lbfgs(problem, &w0, &LbfgsConfig { max_iters: iters, ..Default::default() })
                .expect("lbfgs"),
        );
    }
    traces
}

fn report(title: &str, traces: &[Trace], csv_path: &str) {
    // f* = best objective any method reached (paper: "difference from best
    // determined optimized value")
    let f_star = traces.iter().map(|t| t.best()).fold(f64::INFINITY, f64::min);
    let series: Vec<Series> = traces
        .iter()
        .map(|t| Series {
            name: t.name.clone(),
            points: t
                .objective
                .iter()
                .enumerate()
                .map(|(i, &f)| (i as f64, (f - f_star).max(1e-16)))
                .collect(),
        })
        .collect();
    println!("{}", render(title, &series, 72, 18, true));
    let mut csv = CsvWriter::create(csv_path, &["solver", "iteration", "objective", "log10_error"])
        .expect("csv");
    for t in traces {
        for (i, &f) in t.objective.iter().enumerate() {
            csv.write_vals(&[
                &t.name,
                &i,
                &f,
                &((f - f_star).max(1e-16)).log10(),
            ])
            .expect("row");
        }
    }
    let p = csv.finish().expect("flush");
    println!("  series written to {p:?}\n");
}

fn main() -> sparkla::Result<()> {
    let args = ArgSpec::new("convergence_suite", "Figure 1 reproduction")
        .opt("rows", "10000", "observations (paper: 10000)")
        .opt("linear-cols", "1024", "linear-problem features (paper: 1024)")
        .opt("logistic-cols", "250", "logistic-problem features (paper: 250)")
        .opt("iters", "100", "outer iterations (Fig. 1 x-axis)")
        .opt("executors", "4", "logical executors")
        .opt("seed", "1", "workload seed")
        .parse();
    let ctx = Context::local("convergence_suite", args.usize("executors"));
    let rows = args.usize("rows");
    let n_lin = args.usize("linear-cols");
    let n_log = args.usize("logistic-cols");
    let iters = args.usize("iters");
    let seed = args.u64("seed");

    println!("== Figure 1 reproduction: {rows} observations, {iters} iterations ==\n");

    // panel 1: logistic (unregularized)
    let (p_log, _) = synth::logistic(&ctx, rows, n_log, Regularizer::None, 8, seed)?;
    let traces = run_all(&p_log, n_log, iters, false);
    report("logistic regression", &traces, "target/experiments/fig1_logistic.csv");

    // panel 2: linear (unregularized least squares, 512 informative)
    let (p_lin, _) = synth::linear(&ctx, rows, n_lin, n_lin / 2, Regularizer::None, 8, seed)?;
    let traces = run_all(&p_lin, n_lin, iters, false);
    report("least squares regression", &traces, "target/experiments/fig1_linear.csv");

    // panel 3: logistic + L2
    let (p_log2, _) = synth::logistic(&ctx, rows, n_log, Regularizer::L2(0.1), 8, seed)?;
    let traces = run_all(&p_log2, n_log, iters, false);
    report("L2-regularized logistic regression", &traces, "target/experiments/fig1_logistic_l2.csv");

    // panel 4: linear + L1 (LASSO) — lbfgs skipped (nonsmooth), as in MLlib
    let (p_l1, _) = synth::linear(&ctx, rows, n_lin, n_lin / 2, Regularizer::L1(10.0), 8, seed)?;
    let traces = run_all(&p_l1, n_lin, iters, true);
    report("L1-regularized least squares (LASSO)", &traces, "target/experiments/fig1_lasso.csv");

    println!("observations to check against the paper's Fig. 1:");
    println!("  1. acceleration converges faster than gra at the same step size");
    println!("  2. automatic restarts (acc_r / acc_rb) help");
    println!("  3. backtracking boosts per-iteration convergence (extra cost not in x-axis)");
    println!("  4. lbfgs generally outperforms the accelerated variants");
    Ok(())
}
