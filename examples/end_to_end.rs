//! END-TO-END VALIDATION DRIVER: exercises every layer of the stack on a
//! real (synthetic but nontrivial) workload and proves they compose:
//!
//!   L1/L2  Pallas/JAX AOT artifacts (requires `make artifacts`)
//!   runtime PJRT service thread executing them from executor tasks
//!   L3     RDD substrate + distributed matrices + optimizers, with
//!          fault injection ON for the training phase
//!
//! Workload: distributed logistic regression, 50k x 250, trained with
//! L-BFGS through the fused XLA loss+grad kernel, loss curve logged; then
//! a Table-1-style sparse SVD; both cross-checked against native kernels.
//! Results are recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```

use sparkla::config::ClusterConfig;
use sparkla::distributed::svd::arpack_svd;
use sparkla::distributed::CoordinateMatrix;
use sparkla::linalg::vector::Vector;
use sparkla::optim::lbfgs::{lbfgs, LbfgsConfig};
use sparkla::optim::problem::synth;
use sparkla::optim::Regularizer;
use sparkla::util::argparse::ArgSpec;
use sparkla::util::csv::CsvWriter;
use sparkla::util::timer::Timer;
use sparkla::Context;

fn main() -> sparkla::Result<()> {
    let args = ArgSpec::new("end_to_end", "full-stack validation driver")
        .opt("rows", "20000", "training rows")
        .opt("cols", "250", "features")
        .opt("iters", "25", "L-BFGS iterations")
        .opt("executors", "4", "logical executors")
        .flag("no-xla", "skip the XLA layer (native-only run)")
        .parse();
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let use_xla = !args.flag("no-xla") && artifacts.join("manifest.txt").exists();
    if !use_xla {
        println!("[!] running WITHOUT the XLA layer (run `make artifacts` for the full stack)");
    }

    // fault injection ON: the run must survive executor crashes
    let mut cfg = ClusterConfig {
        num_executors: args.usize("executors"),
        use_xla,
        artifacts_dir: artifacts.to_string_lossy().into_owned(),
        ..Default::default()
    };
    cfg.fault.task_fail_prob = 0.01;
    cfg.fault.executor_kill_prob = 0.005;
    cfg.max_task_retries = 10;
    let ctx = Context::with_config(cfg);
    if use_xla {
        ctx.runtime_required()?; // fail fast if the XLA layer can't start
        println!("[ok] PJRT runtime up: {} artifacts", ctx.runtime().unwrap().manifest().artifacts.len());
    }

    // ---- phase 1: distributed logistic regression training ----------
    let (rows, cols, iters) = (args.usize("rows"), args.usize("cols"), args.usize("iters"));
    println!("\n== phase 1: logistic regression {rows}x{cols}, L-BFGS x{iters}, faults ON ==");
    let t = Timer::start();
    let (problem, _) = synth::logistic(&ctx, rows, cols, Regularizer::L2(1e-3), 16, 99)?;
    let trace = lbfgs(&problem, &Vector::zeros(cols), &LbfgsConfig { max_iters: iters, ..Default::default() })?;
    let train_secs = t.secs();
    let mut csv = CsvWriter::create("target/experiments/e2e_loss_curve.csv", &["iteration", "loss"])?;
    for (i, &l) in trace.objective.iter().enumerate() {
        csv.write_vals(&[&i, &l])?;
    }
    let path = csv.finish()?;
    println!("loss: {:.2} -> {:.6} over {} iterations ({} grad evals)", trace.objective[0], trace.objective.last().unwrap(), trace.objective.len() - 1, trace.grad_evals);
    println!("loss curve -> {path:?}");
    println!("training wall time: {train_secs:.2}s");
    let initial = trace.objective[0];
    let final_ = *trace.objective.last().unwrap();
    assert!(final_ < 0.5 * initial, "training must reduce loss substantially");

    // fit quality: mean per-row logistic loss vs the ln(2) random-guess
    // baseline (the synthetic classes are linearly separable, so the
    // trained model should be far below it)
    let mean_loss = final_ / rows as f64;
    println!("mean per-row loss: {:.6} (random guessing = {:.4})", mean_loss, std::f64::consts::LN_2);
    assert!(mean_loss < 0.5 * std::f64::consts::LN_2, "must beat random guessing");

    // ---- phase 2: sparse SVD through the same stack ------------------
    println!("\n== phase 2: sparse SVD (Table-1 shape) through ARPACK reverse communication ==");
    let t = Timer::start();
    let cm = CoordinateMatrix::sprand(&ctx, 57_500, 95, 127_500, 16, 7);
    let rm = cm.to_row_matrix(16)?.cache();
    let svd = arpack_svd(&rm, 5, true)?;
    println!("top-5 singular values: {:?}", svd.s.iter().map(|s| (s * 100.0).round() / 100.0).collect::<Vec<_>>());
    println!("{} distributed mat-vec jobs, {:.2}s total ({:.4}s per op)", svd.matrix_ops, t.secs(), t.secs() / svd.matrix_ops as f64);
    let err = sparkla::distributed::svd::reconstruction_error(&rm, &svd)?;
    println!("rank-5 relative reconstruction error: {err:.4}");

    // ---- verdict ------------------------------------------------------
    let m = ctx.metrics();
    println!("\n== cluster metrics ==\n{}", m.summary());
    let failed = m.tasks_failed.load(std::sync::atomic::Ordering::Relaxed);
    println!("\nVERDICT: all layers composed{}; {failed} injected faults were absorbed by lineage recovery.",
        if use_xla { " (Pallas->HLO->PJRT->RDD->L-BFGS/ARPACK)" } else { " (native kernels)" });
    Ok(())
}
