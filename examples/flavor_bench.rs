//! L1 artifact-flavor ablation (EXPERIMENTS.md section Perf): times the
//! Pallas-kernel artifacts against their jnp-lowered twins through the
//! live PJRT runtime. Requires `make artifacts`.
//!
//! ```bash
//! cargo run --release --example flavor_bench
//! ```

use std::sync::Arc;

use sparkla::linalg::matrix::DenseMatrix;
use sparkla::linalg::vector::Vector;
use sparkla::runtime::{ops, RuntimeHandle};
use sparkla::util::rng::SplitMix64;
use sparkla::util::timer::Timer;

fn main() -> sparkla::Result<()> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("run `make artifacts` first");
        return Ok(());
    }
    let rt = Arc::new(RuntimeHandle::start(dir.to_str().unwrap())?);
    let mut rng = SplitMix64::new(1);
    let a = DenseMatrix::randn(1024, 256, &mut rng);
    let w = Vector::zeros(256);
    let y = Vector::ones(1024);
    let n = 30;
    println!("{:<16} {:>12} {:>12}", "op (1024x256)", "pallas ms", "jnp ms");
    for op in ["gram", "gramvec", "matvec", "quad", "logistic"] {
        let mut cols = vec![];
        for flavor in ["pallas", "jnp"] {
            std::env::set_var("SPARKLA_XLA_FLAVOR", flavor);
            let run = |rt: &Arc<RuntimeHandle>| -> sparkla::Result<()> {
                match op {
                    "gram" => drop(ops::gram(Some(rt), &a)?),
                    "gramvec" => drop(ops::gramvec(Some(rt), &a, &w)?),
                    "matvec" => drop(ops::matvec(Some(rt), &a, &w)?),
                    "quad" => drop(ops::quad_loss_grad(Some(rt), &a, &w, &y)?),
                    _ => drop(ops::logistic_loss_grad(Some(rt), &a, &w, &y)?),
                }
                Ok(())
            };
            run(&rt)?; // warm: compile
            let t = Timer::start();
            for _ in 0..n {
                run(&rt)?;
            }
            cols.push(t.secs() / n as f64 * 1e3);
        }
        println!("{:<16} {:>12.3} {:>12.3}", op, cols[0], cols[1]);
    }
    std::env::remove_var("SPARKLA_XLA_FLAVOR");
    println!("\n(interpret-mode Pallas grids lower to sequential HLO while-loops — the CPU");
    println!(" backend can't fuse them; the jnp twin is one fused dot. On real TPU the");
    println!(" Mosaic-compiled Pallas kernel is the fast path. See EXPERIMENTS.md.)");
    Ok(())
}
