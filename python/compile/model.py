"""Layer-2 JAX compute graphs, built on the Layer-1 Pallas kernels.

Each public function here is one AOT artifact: aot.py jits + lowers it at
a fixed shape to HLO text that the Rust runtime loads via PJRT. Python is
never on the Rust request path — these run once at `make artifacts`.

The graphs are deliberately thin: the paper's L2 is "the per-partition
compute MLlib closes over", i.e. exactly one fused kernel call plus any
cheap glue (bias terms, regularization is applied driver-side in Rust
because it is a vector op).
"""

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels import (
    gemm_pallas,
    gram_pallas,
    matvec_pallas,
    quad_loss_grad_pallas,
    logistic_loss_grad_pallas,
)


def gemm(x, y):
    """Dense matmul — the Fig. 2 benchmark op and BlockMatrix.multiply tile op."""
    return (gemm_pallas(x, y),)


def gram(a):
    """A^T A of a row partition — tall-skinny SVD / column-similarity hot op."""
    return (gram_pallas(a),)


def matvec(a, x):
    """A @ x of a row partition — the ARPACK reverse-communication op."""
    return (matvec_pallas(a, x),)


def gramvec(a, x):
    """A^T (A x) of a row partition — the square-SVD operator op.

    ARPACK mode: eigen-decomposition of A^T A without forming it. One
    fused pass: matvec then the transposed matvec, both Pallas.
    """
    ax = matvec_pallas(a, x)
    # A^T y as a matvec on the BlockSpec-transposed panel: reuse gemm-style
    # contraction via gram-like scheduling would need a second kernel; the
    # transpose contraction is small (n x m panel @ m) — express with dot
    # so XLA fuses it with the pallas output. Zero-padded rows are exact.
    return (ax @ a,)


def quad_loss_grad(a, w, b):
    """(grad, loss) of 1/2||Aw - b||^2 over a row partition."""
    g, l = quad_loss_grad_pallas(a, w, b)
    return (g, l)


def logistic_loss_grad(a, w, y):
    """(grad, loss) of logistic loss over a row partition, labels in {-1,+1}."""
    g, l = logistic_loss_grad_pallas(a, w, y)
    return (g, l)


# ---------------------------------------------------------------------------
# Artifact registry: name -> (fn, example-arg shapes (f32)).
# Shapes are the fixed AOT contract with rust/src/runtime/artifact.rs —
# keep in sync with DESIGN.md section 4 and the Rust `ArtifactSpec` table.
# ---------------------------------------------------------------------------

def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


# ---------------------------------------------------------------------------
# jnp-lowered variants (perf ablation, EXPERIMENTS.md "Perf / L1-L2"):
# interpret-mode Pallas lowers its grid to sequential HLO while-loops,
# which the CPU PJRT backend executes slowly; the same math written in
# plain jnp lowers to a single fused dot that hits XLA's native kernel.
# On a real TPU the Mosaic-compiled Pallas kernel would be the fast path;
# on this CPU testbed the jnp artifacts are, so the Rust runtime prefers
# `*_jnp` when present (SPARKLA_XLA_FLAVOR=pallas forces the kernels).
# ---------------------------------------------------------------------------

def gemm_jnp(x, y):
    return (ref.gemm_ref(x, y),)


def gram_jnp(a):
    return (ref.gram_ref(a),)


def matvec_jnp(a, x):
    return (ref.matvec_ref(a, x),)


def gramvec_jnp(a, x):
    return (a.T @ (a @ x),)


def quad_loss_grad_jnp(a, w, b):
    g, l = ref.quad_loss_grad_ref(a, w, b)
    return (g, l.reshape(1))


def logistic_loss_grad_jnp(a, w, y):
    g, l = ref.logistic_loss_grad_ref(a, w, y)
    return (g, l.reshape(1))


ARTIFACTS = {
    "gemm_256": (gemm, (_f32(256, 256), _f32(256, 256))),
    "gemm_512": (gemm, (_f32(512, 512), _f32(512, 512))),
    "gram_1024x256": (gram, (_f32(1024, 256),)),
    "matvec_1024x256": (matvec, (_f32(1024, 256), _f32(256))),
    "gramvec_1024x256": (gramvec, (_f32(1024, 256), _f32(256))),
    "quad_grad_1024x256": (quad_loss_grad, (_f32(1024, 256), _f32(256), _f32(1024))),
    "logistic_grad_1024x256": (
        logistic_loss_grad,
        (_f32(1024, 256), _f32(256), _f32(1024)),
    ),
    # jnp ablation variants (same signatures)
    "gemm_jnp_256": (gemm_jnp, (_f32(256, 256), _f32(256, 256))),
    "gemm_jnp_512": (gemm_jnp, (_f32(512, 512), _f32(512, 512))),
    "gram_jnp_1024x256": (gram_jnp, (_f32(1024, 256),)),
    "matvec_jnp_1024x256": (matvec_jnp, (_f32(1024, 256), _f32(256))),
    "gramvec_jnp_1024x256": (gramvec_jnp, (_f32(1024, 256), _f32(256))),
    "quad_grad_jnp_1024x256": (quad_loss_grad_jnp, (_f32(1024, 256), _f32(256), _f32(1024))),
    "logistic_grad_jnp_1024x256": (
        logistic_loss_grad_jnp,
        (_f32(1024, 256), _f32(256), _f32(1024)),
    ),
}
