"""Pure-jnp oracles for every Pallas kernel — the correctness ground truth.

pytest (python/tests/test_kernels.py) sweeps shapes/dtypes with hypothesis
and asserts allclose between kernels.* and these.
"""

import jax
import jax.numpy as jnp


def gemm_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


def matvec_ref(a: jax.Array, x: jax.Array) -> jax.Array:
    return a @ x


def gram_ref(a: jax.Array) -> jax.Array:
    return jnp.dot(a.T, a, preferred_element_type=jnp.float32)


def quad_loss_grad_ref(a, w, b):
    r = a @ w - b
    return a.T @ r, 0.5 * jnp.sum(r * r)


def logistic_loss_grad_ref(a, w, y):
    margin = a @ w
    z = y * margin
    loss = jnp.sum(jnp.log1p(jnp.exp(-jnp.abs(z))) + jnp.maximum(-z, 0.0))
    s = jax.nn.sigmoid(margin)
    labels01 = 0.5 * (y + 1.0)
    grad = a.T @ (s - labels01)
    return grad, loss
