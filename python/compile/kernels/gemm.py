"""Tiled Pallas GEMM — the hot-spot the paper serves with hardware BLAS.

Hardware adaptation (DESIGN.md section "Hardware adaptation"): the paper's
Fig. 2 GEMM backends (OpenBLAS / MKL / cuBLAS) tile for L2 cache or for
GPU threadblock shared memory. On TPU the analogous resource is VMEM and
the compute engine is the 128x128 MXU systolic array, so the kernel below

  * tiles the output into (BM, BN) blocks, one grid cell per block,
  * streams (BM, BK) x (BK, BN) panels of the operands HBM->VMEM via
    BlockSpec index maps (this is the threadblock-loop the paper's CUDA
    backends express with blockIdx),
  * accumulates over the K grid axis in the f32 output ref, relying on
    grid-dimension sequential semantics for the K loop.

Lowered with interpret=True for CPU PJRT execution (Mosaic custom-calls
only run on real TPU); structure, not interpret-mode wallclock, is what
we optimize. See EXPERIMENTS.md "Perf / L1" for the VMEM/MXU accounting.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-native tile. 128x128 keeps each operand panel at
# 128*128*4 B = 64 KiB, three panels well under the ~16 MiB VMEM budget
# and aligned with the systolic array so every pass is a full MXU issue.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _gemm_kernel(x_ref, y_ref, o_ref, *, n_k: int):
    """One (BM, BN) output tile: accumulate x_tile @ y_tile over the K axis.

    The K grid axis is the innermost (fastest-varying) loop, so for a fixed
    (i, j) output tile the kernel sees k = 0..n_k-1 sequentially and can
    use o_ref itself as the accumulator.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # MXU issue: bf16/f32 matmul on a (BM, BK) x (BK, BN) panel pair.
    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def gemm_pallas(
    x: jax.Array,
    y: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
) -> jax.Array:
    """C = X @ Y with a 3-D (M/BM, N/BN, K/BK) Pallas grid.

    Shapes must be multiples of the block sizes; the Rust runtime pads
    partitions to the artifact shape (zero padding is exact for matmul).
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner dims mismatch: {x.shape} @ {y.shape}"
    bm = min(bm, m)
    bn = min(bn, n)
    bk = min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shape ({m},{k})x({k},{n}) not divisible by blocks ({bm},{bn},{bk})"
    )
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_gemm_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)


def _matvec_kernel(a_ref, x_ref, o_ref):
    """One (BM,) slice of y = A @ x. x is small (fits VMEM whole)."""
    o_ref[...] = a_ref[...] @ x_ref[...]


@jax.jit
def matvec_pallas(a: jax.Array, x: jax.Array) -> jax.Array:
    """y = A @ x, tiled over rows.

    This is the ARPACK reverse-communication hot op: the driver ships one
    of these per row-partition per Lanczos iteration. The vector operand
    is broadcast whole into VMEM (the paper's core assumption: vectors fit
    on one machine, matrices do not).
    """
    m, n = a.shape
    bm = min(DEFAULT_BM, m)
    assert m % bm == 0, f"rows {m} not divisible by block {bm}"
    return pl.pallas_call(
        _matvec_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=True,
    )(a, x)
