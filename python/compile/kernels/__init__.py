"""Layer-1 Pallas kernels for sparkla.

Every kernel here is authored with jax.experimental.pallas and lowered in
interpret mode (the CPU PJRT plugin cannot execute Mosaic custom-calls;
see DESIGN.md section 4). The kernels are the compute hot-spots the paper
pushes to hardware BLAS: tiled GEMM, Gram matrix (A^T A), mat-vec, and the
fused loss+gradient kernels used by the distributed optimizers.

`ref.py` holds the pure-jnp oracles used by pytest.
"""

from .gemm import gemm_pallas, matvec_pallas
from .gram import gram_pallas
from .grad import quad_loss_grad_pallas, logistic_loss_grad_pallas

__all__ = [
    "gemm_pallas",
    "matvec_pallas",
    "gram_pallas",
    "quad_loss_grad_pallas",
    "logistic_loss_grad_pallas",
]
