"""Fused loss + gradient Pallas kernels for the convex-optimization path.

Paper section 3.3: F(w) = sum_i F_i(w); each executor computes the
gradient contribution of its row partition, the driver tree-aggregates
and takes the (local, cheap) vector step. These kernels are the executor
side of that split, fused so one HBM pass over the partition produces
both the loss contribution and the gradient contribution.

Fusion layout: a 1-D grid over row panels; a VMEM scratch accumulator
would be natural on real TPU, here we accumulate into the output refs
across sequential grid steps (same trick as gemm.py).

quad:      loss = 1/2 ||A w - b||^2,          grad = A^T (A w - b)
logistic:  loss = sum log(1 + exp(-y (A w))), grad = A^T (s - l)  with
           s = sigmoid(A w), l = (y + 1) / 2  (labels y in {-1, +1})
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 128


def _quad_kernel(a_ref, w_ref, b_ref, g_ref, loss_ref):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        loss_ref[...] = jnp.zeros_like(loss_ref)

    r = a_ref[...] @ w_ref[...] - b_ref[...]          # (BM,)
    g_ref[...] += r @ a_ref[...]                      # A_panel^T r
    loss_ref[...] += 0.5 * jnp.sum(r * r)


@functools.partial(jax.jit, static_argnames=("bm",))
def quad_loss_grad_pallas(a, w, b, *, bm: int = DEFAULT_BM):
    """Returns (grad (n,), loss (1,)) for 1/2 ||A w - b||^2 over a row block."""
    m, n = a.shape
    bm = min(bm, m)
    assert m % bm == 0
    grid = (m // bm,)
    return pl.pallas_call(
        _quad_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n), lambda k: (k, 0)),
            pl.BlockSpec((n,), lambda k: (0,)),
            pl.BlockSpec((bm,), lambda k: (k,)),
        ],
        out_specs=[
            pl.BlockSpec((n,), lambda k: (0,)),
            pl.BlockSpec((1,), lambda k: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        interpret=True,
    )(a, w, b)


def _logistic_kernel(a_ref, w_ref, y_ref, g_ref, loss_ref):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        loss_ref[...] = jnp.zeros_like(loss_ref)

    margin = a_ref[...] @ w_ref[...]                  # (BM,)
    y = y_ref[...]
    # log(1 + exp(-y m)) computed stably: log1p(exp(-|z|)) + max(0, -z)
    z = y * margin
    loss_ref[...] += jnp.sum(jnp.log1p(jnp.exp(-jnp.abs(z))) + jnp.maximum(-z, 0.0))
    s = jax.nn.sigmoid(margin)
    labels01 = 0.5 * (y + 1.0)
    g_ref[...] += (s - labels01) @ a_ref[...]


@functools.partial(jax.jit, static_argnames=("bm",))
def logistic_loss_grad_pallas(a, w, y, *, bm: int = DEFAULT_BM):
    """Returns (grad (n,), loss (1,)) for logistic loss with labels in {-1,+1}.

    Padding contract: padded rows must carry y = +1 and all-zero features,
    which contribute sigmoid(0) - 1 = -1/2 times a zero row to the
    gradient and log(2) to the loss... which would be WRONG. The runtime
    therefore passes a y of +1 and a *mask* via the label: padded rows use
    y = 0, making z = 0 contribute log1p(exp(0)) + 0 = log 2 as well.
    Instead we adopt the simpler exact contract used by the Rust runtime:
    padded rows have zero features AND y = +1, and the runtime subtracts
    n_pad * log(2) from the returned loss and n_pad * (-1/2) * 0 = 0 from
    the gradient (zero rows contribute nothing to A^T(...)). See
    rust/src/runtime/ops.rs.
    """
    m, n = a.shape
    bm = min(bm, m)
    assert m % bm == 0
    grid = (m // bm,)
    return pl.pallas_call(
        _logistic_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n), lambda k: (k, 0)),
            pl.BlockSpec((n,), lambda k: (0,)),
            pl.BlockSpec((bm,), lambda k: (k,)),
        ],
        out_specs=[
            pl.BlockSpec((n,), lambda k: (0,)),
            pl.BlockSpec((1,), lambda k: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        interpret=True,
    )(a, w, y)
