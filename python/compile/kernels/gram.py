"""Gram matrix A^T A as a Pallas kernel.

This is the tall-skinny SVD hot-spot (paper section 3.1.2): each executor
computes the Gram contribution of its row block; the driver sums the
(n x n) results and eigendecomposes locally. The paper computes it with
one all-to-one communication (DIMSUM, refs [10, 11]); here the kernel is
the per-partition compute and the Rust tree_aggregate is the
communication.

Grid layout: (n/BN1, n/BN2, m/BM). For a fixed output tile (i, j) the row
axis k runs sequentially, so the output ref doubles as the accumulator —
the same schedule as gemm.py with X = A^T expressed via index maps rather
than a materialized transpose (transposes are free in BlockSpec space;
the paper pays a shuffle for them).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BN = 128
DEFAULT_BM = 128


def _gram_kernel(a_col_i_ref, a_col_j_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # (BN1, BM) @ (BM, BN2): contract over the row panel.
    o_ref[...] += jnp.dot(
        a_col_i_ref[...].T, a_col_j_ref[...],
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("bn", "bm"))
def gram_pallas(a: jax.Array, *, bn: int = DEFAULT_BN, bm: int = DEFAULT_BM) -> jax.Array:
    """G = A^T A for a (m, n) row block, m >> n typically."""
    m, n = a.shape
    bn = min(bn, n)
    bm = min(bm, m)
    assert n % bn == 0 and m % bm == 0, (
        f"gram shape ({m},{n}) not divisible by blocks ({bm},{bn})"
    )
    grid = (n // bn, n // bn, m // bm)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            # column panel i: rows k-block, cols i-block
            pl.BlockSpec((bm, bn), lambda i, j, k: (k, i)),
            # column panel j: rows k-block, cols j-block
            pl.BlockSpec((bm, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bn, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,
    )(a, a)
