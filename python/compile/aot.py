"""AOT lowering: JAX (L2+L1) -> HLO text artifacts for the Rust runtime.

HLO *text* is the interchange format — NOT lowered.compile() serialization
and NOT serialized HloModuleProto: jax >= 0.5 emits protos with 64-bit
instruction ids which xla_extension 0.5.1 (what the published `xla` 0.1.6
crate links) rejects (`proto.id() <= INT_MAX`). The text parser reassigns
ids, so text round-trips cleanly. See /opt/xla-example/README.md.

Also writes artifacts/manifest.txt: one line per artifact,
  name <TAB> file <TAB> in_shapes <TAB> out_shapes
e.g.  gemm_256\tgemm_256.hlo.txt\tf32[256,256];f32[256,256]\tf32[256,256]
The Rust ArtifactRegistry parses this to validate its compiled-in specs.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from .model import ARTIFACTS


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _fmt_shapes(specs) -> str:
    out = []
    for s in specs:
        dims = ",".join(str(d) for d in s.shape)
        out.append(f"f32[{dims}]")
    return ";".join(out)


def lower_all(out_dir: str, only=None) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest_lines = []
    for name, (fn, arg_specs) in sorted(ARTIFACTS.items()):
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        out_specs = jax.eval_shape(fn, *arg_specs)
        manifest_lines.append(
            "\t".join([name, fname, _fmt_shapes(arg_specs), _fmt_shapes(out_specs)])
        )
        print(f"  lowered {name:<28} {len(text):>9} chars -> {fname}")
    manifest = os.path.join(out_dir, "manifest.txt")
    with open(manifest, "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {manifest} ({len(manifest_lines)} artifacts)")


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--only", nargs="*", help="lower only these artifact names")
    args = p.parse_args()
    lower_all(args.out_dir, only=args.only)


if __name__ == "__main__":
    main()
