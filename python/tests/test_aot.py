"""AOT pipeline checks: HLO text artifacts parse, manifest is consistent."""

import os
import re

import pytest

from compile import aot, model

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _have_artifacts():
    return os.path.exists(os.path.join(ART_DIR, "manifest.txt"))


pytestmark = pytest.mark.skipif(
    not _have_artifacts(), reason="run `make artifacts` first"
)


def _manifest():
    with open(os.path.join(ART_DIR, "manifest.txt")) as f:
        rows = [line.strip().split("\t") for line in f if line.strip()]
    return {r[0]: r[1:] for r in rows}


def test_manifest_covers_registry():
    assert set(_manifest()) == set(model.ARTIFACTS)


def test_artifact_files_exist_and_are_hlo_text():
    for name, (fname, _ins, _outs) in _manifest().items():
        path = os.path.join(ART_DIR, fname)
        assert os.path.exists(path), path
        head = open(path).read(200)
        # HLO text modules start with `HloModule <name>`
        assert head.startswith("HloModule"), f"{name}: not HLO text: {head[:40]!r}"


def test_manifest_shapes_match_registry():
    man = _manifest()
    for name, (fn, specs) in model.ARTIFACTS.items():
        ins = man[name][1]
        want = aot._fmt_shapes(specs)
        assert ins == want, f"{name}: manifest {ins} != registry {want}"


def test_hlo_entry_shapes_match_manifest():
    """Parse the ENTRY line of each HLO module and cross-check row/col sizes."""
    man = _manifest()
    for name, (fname, ins, _outs) in man.items():
        text = open(os.path.join(ART_DIR, fname)).read()
        m = re.search(r"entry_computation_layout=\{\((.*?)\)->", text)
        assert m, f"{name}: no entry_computation_layout"
        entry_params = m.group(1)
        for spec in ins.split(";"):
            dims = spec[spec.index("[") :]
            assert dims in entry_params, f"{name}: {dims} not in ENTRY({entry_params})"


def test_no_mosaic_custom_calls():
    """interpret=True must hold: a Mosaic custom-call would be unrunnable
    on the CPU PJRT client the Rust runtime uses."""
    for name, (fname, _ins, _outs) in _manifest().items():
        text = open(os.path.join(ART_DIR, fname)).read()
        assert "tpu_custom_call" not in text and "mosaic" not in text.lower(), name
