"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

hypothesis sweeps shapes (multiples of the block size and degenerate
single-block cases) and data; assert_allclose against kernels/ref.py.
This is the CORE correctness signal for the compute layer.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    gemm_pallas,
    matvec_pallas,
    gram_pallas,
    quad_loss_grad_pallas,
    logistic_loss_grad_pallas,
)
from compile.kernels import ref

SETTINGS = dict(max_examples=20, deadline=None)


def _arr(rng, *shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale)


# --------------------------------------------------------------------- GEMM

@settings(**SETTINGS)
@given(
    mi=st.integers(1, 3), ni=st.integers(1, 3), ki=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_gemm_block_multiples(mi, ni, ki, seed):
    rng = np.random.default_rng(seed)
    m, n, k = 128 * mi, 128 * ni, 128 * ki
    x, y = _arr(rng, m, k), _arr(rng, k, n)
    got = gemm_pallas(x, y)
    np.testing.assert_allclose(got, ref.gemm_ref(x, y), rtol=2e-4, atol=2e-3)


@pytest.mark.parametrize("shape", [(128, 128, 128), (256, 128, 384)])
def test_gemm_identity(shape):
    m, k, n = shape
    x = jnp.eye(m, k, dtype=jnp.float32)
    y = jnp.arange(k * n, dtype=jnp.float32).reshape(k, n) / (k * n)
    got = gemm_pallas(x, y)
    want = ref.gemm_ref(x, y)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_gemm_zero():
    z = jnp.zeros((128, 128), jnp.float32)
    np.testing.assert_array_equal(gemm_pallas(z, z), z)


@pytest.mark.parametrize("bm,bn,bk", [(64, 64, 64), (128, 64, 128), (32, 128, 64)])
def test_gemm_block_shapes(bm, bn, bk):
    """Tiling must not change the result — the Fig. 2 tuning knob."""
    rng = np.random.default_rng(0)
    x, y = _arr(rng, 128, 128), _arr(rng, 128, 128)
    got = gemm_pallas(x, y, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(got, ref.gemm_ref(x, y), rtol=2e-4, atol=2e-3)


def test_gemm_rejects_inner_dim_mismatch():
    x = jnp.zeros((128, 100), jnp.float32)
    y = jnp.zeros((128, 128), jnp.float32)
    with pytest.raises(AssertionError):
        gemm_pallas(x, y)


def test_gemm_non_multiple_shapes_fall_back_to_single_block():
    """Shapes smaller than the tile shrink the block (bm=min(bm,m))."""
    rng = np.random.default_rng(11)
    x, y = _arr(rng, 100, 60), _arr(rng, 60, 36)
    got = gemm_pallas(x, y)
    np.testing.assert_allclose(got, ref.gemm_ref(x, y), rtol=2e-4, atol=1e-3)


# ------------------------------------------------------------------- MATVEC

@settings(**SETTINGS)
@given(mi=st.integers(1, 8), n=st.sampled_from([16, 64, 256]), seed=st.integers(0, 2**31 - 1))
def test_matvec(mi, n, seed):
    rng = np.random.default_rng(seed)
    a, x = _arr(rng, 128 * mi, n), _arr(rng, n)
    got = matvec_pallas(a, x)
    np.testing.assert_allclose(got, ref.matvec_ref(a, x), rtol=2e-4, atol=2e-3)


def test_matvec_small_single_block():
    rng = np.random.default_rng(7)
    a, x = _arr(rng, 64, 32), _arr(rng, 32)   # m < BM -> single block
    np.testing.assert_allclose(matvec_pallas(a, x), ref.matvec_ref(a, x), rtol=2e-4, atol=1e-3)


# --------------------------------------------------------------------- GRAM

@settings(**SETTINGS)
@given(mi=st.integers(1, 6), ni=st.integers(1, 2), seed=st.integers(0, 2**31 - 1))
def test_gram(mi, ni, seed):
    rng = np.random.default_rng(seed)
    a = _arr(rng, 128 * mi, 128 * ni)
    got = gram_pallas(a)
    np.testing.assert_allclose(got, ref.gram_ref(a), rtol=2e-4, atol=5e-3)


def test_gram_symmetry_and_psd_diagonal():
    rng = np.random.default_rng(1)
    a = _arr(rng, 256, 128)
    g = np.asarray(gram_pallas(a))
    np.testing.assert_allclose(g, g.T, rtol=1e-5, atol=1e-5)
    assert (np.diag(g) >= -1e-5).all()


def test_gram_zero_padding_exact():
    """Zero-padded rows must not change A^T A — the runtime's padding contract."""
    rng = np.random.default_rng(2)
    a = _arr(rng, 128, 128)
    padded = jnp.concatenate([a, jnp.zeros((128, 128), jnp.float32)])
    np.testing.assert_allclose(gram_pallas(padded), gram_pallas(a), rtol=1e-5, atol=1e-4)


# ----------------------------------------------------------- LOSS+GRAD quad

@settings(**SETTINGS)
@given(mi=st.integers(1, 6), n=st.sampled_from([32, 128, 256]), seed=st.integers(0, 2**31 - 1))
def test_quad_loss_grad(mi, n, seed):
    rng = np.random.default_rng(seed)
    m = 128 * mi
    a, w, b = _arr(rng, m, n), _arr(rng, n), _arr(rng, m)
    g, l = quad_loss_grad_pallas(a, w, b)
    g_ref, l_ref = ref.quad_loss_grad_ref(a, w, b)
    np.testing.assert_allclose(g, g_ref, rtol=3e-4, atol=5e-3)
    np.testing.assert_allclose(l[0], l_ref, rtol=3e-4, atol=5e-3)


def test_quad_grad_matches_autodiff():
    rng = np.random.default_rng(3)
    a, w, b = _arr(rng, 128, 64), _arr(rng, 64), _arr(rng, 128)
    g, _ = quad_loss_grad_pallas(a, w, b)
    g_ad = jax.grad(lambda w_: 0.5 * jnp.sum((a @ w_ - b) ** 2))(w)
    np.testing.assert_allclose(g, g_ad, rtol=3e-4, atol=3e-3)


def test_quad_zero_padding_exact():
    rng = np.random.default_rng(4)
    a, w, b = _arr(rng, 128, 64), _arr(rng, 64), _arr(rng, 128)
    ap = jnp.concatenate([a, jnp.zeros((128, 64), jnp.float32)])
    bp = jnp.concatenate([b, jnp.zeros((128,), jnp.float32)])
    g, l = quad_loss_grad_pallas(a, w, b)
    gp, lp = quad_loss_grad_pallas(ap, w, bp)
    np.testing.assert_allclose(gp, g, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(lp, l, rtol=1e-5, atol=1e-4)


# ------------------------------------------------------- LOSS+GRAD logistic

@settings(**SETTINGS)
@given(mi=st.integers(1, 4), n=st.sampled_from([32, 128]), seed=st.integers(0, 2**31 - 1))
def test_logistic_loss_grad(mi, n, seed):
    rng = np.random.default_rng(seed)
    m = 128 * mi
    a, w = _arr(rng, m, n), _arr(rng, n, scale=0.1)
    y = jnp.asarray(rng.choice([-1.0, 1.0], size=m).astype(np.float32))
    g, l = logistic_loss_grad_pallas(a, w, y)
    g_ref, l_ref = ref.logistic_loss_grad_ref(a, w, y)
    np.testing.assert_allclose(g, g_ref, rtol=3e-4, atol=5e-3)
    np.testing.assert_allclose(l[0], l_ref, rtol=3e-4, atol=5e-3)


def test_logistic_grad_matches_autodiff():
    rng = np.random.default_rng(5)
    a, w = _arr(rng, 128, 32), _arr(rng, 32, scale=0.1)
    y = jnp.asarray(rng.choice([-1.0, 1.0], size=128).astype(np.float32))
    g, _ = logistic_loss_grad_pallas(a, w, y)
    loss_fn = lambda w_: jnp.sum(jnp.log1p(jnp.exp(-y * (a @ w_))))
    np.testing.assert_allclose(g, jax.grad(loss_fn)(w), rtol=3e-4, atol=3e-3)


def test_logistic_loss_extreme_margins_stable():
    """Stable log1p(exp(.)) formulation: no inf/nan at huge margins."""
    a = jnp.ones((128, 4), jnp.float32) * 100.0
    w = jnp.ones((4,), jnp.float32) * 100.0
    y = jnp.asarray([1.0, -1.0] * 64, jnp.float32)
    g, l = logistic_loss_grad_pallas(a, w, y)
    assert np.isfinite(np.asarray(g)).all()
    assert np.isfinite(np.asarray(l)).all()


def test_logistic_padding_contract():
    """Padded rows (zero features, y=+1) add exactly log(2) each to loss
    and nothing to the gradient — what rust/src/runtime/ops.rs subtracts."""
    rng = np.random.default_rng(6)
    a, w = _arr(rng, 128, 32), _arr(rng, 32, scale=0.1)
    y = jnp.asarray(rng.choice([-1.0, 1.0], size=128).astype(np.float32))
    n_pad = 128
    ap = jnp.concatenate([a, jnp.zeros((n_pad, 32), jnp.float32)])
    yp = jnp.concatenate([y, jnp.ones((n_pad,), jnp.float32)])
    g, l = logistic_loss_grad_pallas(a, w, y)
    gp, lp = logistic_loss_grad_pallas(ap, w, yp)
    np.testing.assert_allclose(gp, g, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(lp[0] - n_pad * np.log(2.0, dtype=np.float32), l[0], rtol=1e-4, atol=1e-2)
