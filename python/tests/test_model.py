"""L2 correctness: model graphs vs jnp oracles + artifact registry shape checks."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref


def _rng_arrs(seed, *shapes):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.standard_normal(s, dtype=np.float32)) for s in shapes]


def test_gemm_model():
    x, y = _rng_arrs(0, (256, 256), (256, 256))
    (got,) = model.gemm(x, y)
    np.testing.assert_allclose(got, ref.gemm_ref(x, y), rtol=2e-4, atol=3e-3)


def test_gram_model():
    (a,) = _rng_arrs(1, (1024, 256))
    (got,) = model.gram(a)
    np.testing.assert_allclose(got, ref.gram_ref(a), rtol=3e-4, atol=2e-2)


def test_matvec_model():
    a, x = _rng_arrs(2, (1024, 256), (256,))
    (got,) = model.matvec(a, x)
    np.testing.assert_allclose(got, ref.matvec_ref(a, x), rtol=3e-4, atol=5e-3)


def test_gramvec_model():
    """gramvec = A^T (A x): the square-SVD ARPACK operator."""
    a, x = _rng_arrs(3, (1024, 256), (256,))
    (got,) = model.gramvec(a, x)
    want = a.T @ (a @ x)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-2)


def test_quad_grad_model():
    a, w, b = _rng_arrs(4, (1024, 256), (256,), (1024,))
    g, l = model.quad_loss_grad(a, w, b)
    g_ref, l_ref = ref.quad_loss_grad_ref(a, w, b)
    np.testing.assert_allclose(g, g_ref, rtol=5e-4, atol=5e-2)
    np.testing.assert_allclose(l[0], l_ref, rtol=5e-4, atol=5e-2)


def test_logistic_grad_model():
    rng = np.random.default_rng(5)
    a = jnp.asarray(rng.standard_normal((1024, 256), dtype=np.float32))
    w = jnp.asarray(rng.standard_normal(256, dtype=np.float32) * 0.05)
    y = jnp.asarray(rng.choice([-1.0, 1.0], size=1024).astype(np.float32))
    g, l = model.logistic_loss_grad(a, w, y)
    g_ref, l_ref = ref.logistic_loss_grad_ref(a, w, y)
    np.testing.assert_allclose(g, g_ref, rtol=5e-4, atol=3e-2)
    np.testing.assert_allclose(l[0], l_ref, rtol=5e-4, atol=3e-2)


# ----------------------------------------------------------------- registry

def test_artifact_registry_shapes_evaluate():
    """Every registered artifact must trace at its declared shapes and
    produce only f32 outputs (the Rust loader assumes f32 throughout)."""
    for name, (fn, specs) in model.ARTIFACTS.items():
        out = jax.eval_shape(fn, *specs)
        assert isinstance(out, tuple) and len(out) >= 1, name
        for o in out:
            assert o.dtype == jnp.float32, f"{name}: {o.dtype}"


def test_artifact_names_match_design_contract():
    pallas = {
        "gemm_256", "gemm_512", "gram_1024x256", "matvec_1024x256",
        "gramvec_1024x256", "quad_grad_1024x256", "logistic_grad_1024x256",
    }
    # every pallas artifact has a jnp ablation twin (EXPERIMENTS.md §Perf)
    jnp_variants = {
        "gemm_jnp_256", "gemm_jnp_512", "gram_jnp_1024x256",
        "matvec_jnp_1024x256", "gramvec_jnp_1024x256",
        "quad_grad_jnp_1024x256", "logistic_grad_jnp_1024x256",
    }
    expected = pallas | jnp_variants
    assert expected == set(model.ARTIFACTS), (
        "artifact set drifted — update DESIGN.md section 4 and "
        "rust/src/runtime/artifact.rs together with this test"
    )


def test_partition_shapes_are_block_multiples():
    """AOT shapes must be divisible by the kernels' default blocks."""
    for name, (_, specs) in model.ARTIFACTS.items():
        a = specs[0]
        if len(a.shape) == 2:
            assert a.shape[0] % 128 == 0, name
